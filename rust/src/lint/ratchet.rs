//! The ratchet baseline file (`lint_ratchet.toml`).
//!
//! A hand-rolled reader/writer for the tiny TOML subset the ratchet
//! needs — quoted section headers and `key = integer` pairs — so the
//! linter stays dependency-free in the offline build.  Two section
//! kinds share the file:
//!
//! ```toml
//! ["sim/master.rs"]          # EVT-UNWRAP-RATCHET: per-file counts
//! unwrap = 0
//! expect = 2
//!
//! ["panic-reach:SimCluster::handle"]   # PANIC-REACH: per-root counts
//! reachable = 394
//! ```
//!
//! File paths are relative to `src/`; panic-reach sections are keyed by
//! the dispatch-root name under a `panic-reach:` prefix (legal because
//! `:` cannot appear in a repo-relative path, so the namespaces cannot
//! collide).  The contract is one-directional for both kinds: counts in
//! the tree may only move *down* relative to the committed baseline.
//! `nephele lint` fails when a budget is exceeded, suggests the lowered
//! baseline when the live count dips below it, and `--update-ratchet`
//! rewrites this file with the (lower) live counts.

use std::collections::BTreeMap;

/// Per-file unwrap/expect budget.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Budget {
    pub unwrap: u64,
    pub expect: u64,
}

/// Prefix distinguishing panic-reach sections from file sections.
pub const ROOT_PREFIX: &str = "panic-reach:";

/// The full baseline, ordered: `src/`-relative path → unwrap budget,
/// plus dispatch root → reachable-panic-site budget.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Ratchet {
    pub files: BTreeMap<String, Budget>,
    pub roots: BTreeMap<String, u64>,
}

/// Parse the ratchet file.  Unknown keys, malformed headers and
/// non-integer values are hard errors — a typo in the baseline must not
/// silently grant an unlimited budget.
pub fn parse(text: &str) -> Result<Ratchet, String> {
    let mut out = Ratchet::default();
    let mut current: Option<String> = None;
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        let lineno = i + 1;
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let inner = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {lineno}: unterminated section header"))?
                .trim()
                .trim_matches('"');
            if inner.is_empty() {
                return Err(format!("line {lineno}: empty section header"));
            }
            let dup = match inner.strip_prefix(ROOT_PREFIX) {
                Some(root) => out.roots.insert(root.to_string(), 0).is_some(),
                None => out.files.insert(inner.to_string(), Budget::default()).is_some(),
            };
            if dup {
                return Err(format!("line {lineno}: duplicate section {inner:?}"));
            }
            current = Some(inner.to_string());
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {lineno}: expected `key = value`"))?;
        let section = current
            .as_ref()
            .ok_or_else(|| format!("line {lineno}: key outside any [\"...\"] section"))?;
        let n: u64 = value
            .trim()
            .parse()
            .map_err(|_| format!("line {lineno}: value is not an unsigned integer"))?;
        match section.strip_prefix(ROOT_PREFIX) {
            Some(root) => {
                let budget =
                    out.roots.get_mut(root).expect("section inserted when header was read");
                match key.trim() {
                    "reachable" => *budget = n,
                    other => return Err(format!("line {lineno}: unknown key {other:?}")),
                }
            }
            None => {
                let budget = out
                    .files
                    .get_mut(section.as_str())
                    .expect("section inserted when header was read");
                match key.trim() {
                    "unwrap" => budget.unwrap = n,
                    "expect" => budget.expect = n,
                    other => return Err(format!("line {lineno}: unknown key {other:?}")),
                }
            }
        }
    }
    Ok(out)
}

/// Deterministic serialization (sorted by path, then by root; fixed key
/// order).
pub fn render(r: &Ratchet) -> String {
    let mut out = String::from(
        "# nephele-lint ratchet baselines.  Counts may only decrease; run\n\
         # `nephele lint --update-ratchet` after burning debt down.  Raising a\n\
         # budget is a reviewed edit of this file, never an automated one.\n\
         #\n\
         # [\"<file>\"] sections: whole-file `.unwrap()` / `.expect(` counts\n\
         # (EVT-UNWRAP-RATCHET, whole src/ tree).\n\
         # [\"panic-reach:<root>\"] sections: panic sites transitively reachable\n\
         # from each event-dispatch root (PANIC-REACH).\n",
    );
    for (file, b) in &r.files {
        out.push_str(&format!("\n[\"{file}\"]\nunwrap = {}\nexpect = {}\n", b.unwrap, b.expect));
    }
    for (root, n) in &r.roots {
        out.push_str(&format!("\n[\"{ROOT_PREFIX}{root}\"]\nreachable = {n}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_render_roundtrip() {
        let mut r = Ratchet::default();
        r.files.insert("sim/cluster.rs".into(), Budget { unwrap: 48, expect: 0 });
        r.files.insert("sim/master.rs".into(), Budget { unwrap: 0, expect: 2 });
        r.roots.insert("SimCluster::handle".into(), 394);
        let text = render(&r);
        assert_eq!(parse(&text).unwrap(), r);
        assert_eq!(render(&parse(&text).unwrap()), text);
    }

    #[test]
    fn malformed_ratchets_are_rejected() {
        assert!(parse("unwrap = 3").is_err(), "key outside a section");
        assert!(parse("[\"a.rs\"]\nunwrap = x").is_err(), "non-integer value");
        assert!(parse("[\"a.rs\"]\nwobble = 3").is_err(), "unknown key");
        assert!(parse("[\"a.rs\"\nunwrap = 3").is_err(), "unterminated header");
        assert!(parse("[\"a.rs\"]\n[\"a.rs\"]").is_err(), "duplicate section");
        assert!(
            parse("[\"panic-reach:main::live\"]\nunwrap = 3").is_err(),
            "file keys are rejected in a panic-reach section"
        );
        assert!(
            parse("[\"a.rs\"]\nreachable = 3").is_err(),
            "panic-reach keys are rejected in a file section"
        );
        assert!(
            parse("[\"panic-reach:x\"]\n[\"panic-reach:x\"]").is_err(),
            "duplicate panic-reach section"
        );
    }

    #[test]
    fn missing_keys_default_to_zero() {
        let r = parse("[\"sim/x.rs\"]\nunwrap = 7\n").unwrap();
        assert_eq!(r.files["sim/x.rs"], Budget { unwrap: 7, expect: 0 });
    }

    #[test]
    fn root_sections_parse_their_reachable_count() {
        let r = parse("[\"panic-reach:main::live\"]\nreachable = 453\n").unwrap();
        assert_eq!(r.roots["main::live"], 453);
        assert!(r.files.is_empty());
    }
}
