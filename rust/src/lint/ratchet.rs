//! The `EVT-UNWRAP-RATCHET` baseline file (`lint_ratchet.toml`).
//!
//! A hand-rolled reader/writer for the tiny TOML subset the ratchet
//! needs — quoted-path section headers and `key = integer` pairs — so
//! the linter stays dependency-free in the offline build:
//!
//! ```toml
//! ["sim/master.rs"]
//! unwrap = 0
//! expect = 2
//! ```
//!
//! Paths are relative to `src/`.  The contract is one-directional:
//! counts in the tree may only move *down* relative to the committed
//! baseline.  `nephele lint` fails when a file exceeds its budget,
//! suggests the lowered baseline when a file dips below it, and
//! `--update-ratchet` rewrites this file with the (lower) live counts.

use std::collections::BTreeMap;

/// Per-file unwrap/expect budget.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Budget {
    pub unwrap: u64,
    pub expect: u64,
}

/// The full baseline: `src/`-relative path → budget, ordered.
pub type Ratchet = BTreeMap<String, Budget>;

/// Parse the ratchet file.  Unknown keys, malformed headers and
/// non-integer values are hard errors — a typo in the baseline must not
/// silently grant an unlimited budget.
pub fn parse(text: &str) -> Result<Ratchet, String> {
    let mut out = Ratchet::new();
    let mut current: Option<String> = None;
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        let lineno = i + 1;
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let inner = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {lineno}: unterminated section header"))?
                .trim()
                .trim_matches('"');
            if inner.is_empty() {
                return Err(format!("line {lineno}: empty section header"));
            }
            if out.contains_key(inner) {
                return Err(format!("line {lineno}: duplicate section {inner:?}"));
            }
            out.insert(inner.to_string(), Budget::default());
            current = Some(inner.to_string());
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {lineno}: expected `key = value`"))?;
        let section = current
            .as_ref()
            .ok_or_else(|| format!("line {lineno}: key outside any [\"file\"] section"))?;
        let n: u64 = value
            .trim()
            .parse()
            .map_err(|_| format!("line {lineno}: value is not an unsigned integer"))?;
        let budget = out.get_mut(section).expect("section inserted when header was read");
        match key.trim() {
            "unwrap" => budget.unwrap = n,
            "expect" => budget.expect = n,
            other => return Err(format!("line {lineno}: unknown key {other:?}")),
        }
    }
    Ok(out)
}

/// Deterministic serialization (sorted by path; fixed key order).
pub fn render(r: &Ratchet) -> String {
    let mut out = String::from(
        "# EVT-UNWRAP-RATCHET baselines: whole-file `.unwrap()` / `.expect(` counts\n\
         # for the event-path modules (src/sim/).  Counts may only decrease; run\n\
         # `nephele lint --update-ratchet` after burning debt down.  Raising a\n\
         # budget is a reviewed edit of this file, never an automated one.\n",
    );
    for (file, b) in r {
        out.push_str(&format!("\n[\"{file}\"]\nunwrap = {}\nexpect = {}\n", b.unwrap, b.expect));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_render_roundtrip() {
        let mut r = Ratchet::new();
        r.insert("sim/cluster.rs".into(), Budget { unwrap: 48, expect: 0 });
        r.insert("sim/master.rs".into(), Budget { unwrap: 0, expect: 2 });
        let text = render(&r);
        assert_eq!(parse(&text).unwrap(), r);
        assert_eq!(render(&parse(&text).unwrap()), text);
    }

    #[test]
    fn malformed_ratchets_are_rejected() {
        assert!(parse("unwrap = 3").is_err(), "key outside a section");
        assert!(parse("[\"a.rs\"]\nunwrap = x").is_err(), "non-integer value");
        assert!(parse("[\"a.rs\"]\nwobble = 3").is_err(), "unknown key");
        assert!(parse("[\"a.rs\"\nunwrap = 3").is_err(), "unterminated header");
        assert!(parse("[\"a.rs\"]\n[\"a.rs\"]").is_err(), "duplicate section");
    }

    #[test]
    fn missing_keys_default_to_zero() {
        let r = parse("[\"sim/x.rs\"]\nunwrap = 7\n").unwrap();
        assert_eq!(r["sim/x.rs"], Budget { unwrap: 7, expect: 0 });
    }
}
