//! The four `nephele-lint` rules.
//!
//! All rules operate on *masked* source lines (string-literal interiors
//! and comments blanked by [`super::SourceFile`]), so trigger tokens
//! inside log messages or docs never fire.  The analysis is a
//! hand-rolled lexical scan — the offline build forbids `syn`/dylint —
//! which buys zero dependencies at the cost of being name-based rather
//! than type-based.  The escape hatch for the resulting (rare) false
//! positives is an explicit, reasoned `lint:allow` suppression; see
//! `DESIGN.md` §11 for each rule's exact semantics and limits.

use super::ratchet::{Budget, Ratchet};
use super::report::Finding;
use super::SourceFile;
use std::collections::BTreeSet;

/// Rule ids, stable across releases (reports, suppressions and fixtures
/// key on them).
pub const DET_HASH_ITER: &str = "DET-HASH-ITER";
pub const DET_WALLCLOCK: &str = "DET-WALLCLOCK";
pub const EVT_UNWRAP_RATCHET: &str = "EVT-UNWRAP-RATCHET";
pub const SHARD_LOCK: &str = "SHARD-LOCK";
/// Meta-rule for malformed suppressions; not itself suppressible.
pub const LINT_SUPPRESS: &str = "LINT-SUPPRESS";

pub const ALL_RULES: [&str; 4] =
    [DET_HASH_ITER, DET_WALLCLOCK, EVT_UNWRAP_RATCHET, SHARD_LOCK];

/// Modules whose event order or fingerprints same-seed replay depends
/// on: the determinism rules apply here.  `src/telemetry/` is in scope
/// because the journal digest and metrics dump are replay fingerprints
/// themselves — a wall-clock read or hash-ordered render there breaks
/// the cross-thread digest guarantee just as surely as in the engine.
const DET_SCOPES: [&str; 5] =
    ["src/sim/", "src/sched/", "src/qos/", "src/actions/", "src/telemetry/"];

/// Modules under the unwrap ratchet: the event path plus the telemetry
/// layer (which observes every decision and must never panic mid-run).
const RATCHET_SCOPES: [&str; 2] = ["src/sim/", "src/telemetry/"];

pub fn in_det_scope(path: &str) -> bool {
    DET_SCOPES.iter().any(|s| path.starts_with(s))
}

pub fn in_ratchet_scope(path: &str) -> bool {
    RATCHET_SCOPES.iter().any(|s| path.starts_with(s))
}

pub fn is_shard_file(path: &str) -> bool {
    path.ends_with("sim/shard.rs")
}

fn is_ident_char(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// The identifier ending at byte `end` (exclusive), if any.
fn ident_ending_at(line: &str, end: usize) -> Option<&str> {
    let b = line.as_bytes();
    let mut start = end;
    while start > 0 && is_ident_char(b[start - 1]) {
        start -= 1;
    }
    if start == end || b[start].is_ascii_digit() {
        None
    } else {
        Some(&line[start..end])
    }
}

// ---------------------------------------------------------------------
// DET-HASH-ITER
// ---------------------------------------------------------------------

/// Collect names *declared* with a `HashMap`/`HashSet` type on a masked
/// line: struct fields, lets, params, struct-literal inits
/// (`name: HashMap<...>` / `name = std::collections::HashSet::new()`).
///
/// With `initializers` set, `=`-introduced bindings count too — that is
/// the per-file (local) mode.  Crate-wide the caller passes `false`, so
/// only `:`-annotated names (fields, typed lets) travel across files; a
/// field declared in `sim/task.rs` is then recognized when iterated as
/// `self.tasks[i].field.iter()` in `sim/worker.rs`, while short local
/// binding names cannot leak into other files' dotted accesses.
pub fn annotated_hash_names(masked_lines: &[String], initializers: bool) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for line in masked_lines {
        for needle in ["HashMap<", "HashSet<", "HashMap::", "HashSet::"] {
            for pos in match_positions(line, needle) {
                if let Some((name, intro)) = decl_name_before(line, pos) {
                    if (intro == b':' || initializers)
                        && !matches!(
                            name,
                            "mut" | "let" | "pub" | "crate" | "collections" | "std"
                        )
                    {
                        names.insert(name.to_string());
                    }
                }
            }
        }
    }
    names
}

/// The name being declared (or assigned) when a `Hash*` type token
/// starts at byte `pos`: walks back over an optional
/// `std::collections::` path to a `:` annotation or `=` initializer and
/// returns the identifier in front of it plus the introducer byte.
/// Return-type positions, tuple/turbofish contexts and `::` paths yield
/// `None`.
fn decl_name_before(line: &str, pos: usize) -> Option<(&str, u8)> {
    let b = line.as_bytes();
    let mut i = pos;
    while i > 0 && (is_ident_char(b[i - 1]) || b[i - 1] == b':') {
        i -= 1;
    }
    while i > 0 && b[i - 1].is_ascii_whitespace() {
        i -= 1;
    }
    if i == 0 {
        return None;
    }
    let intro = match b[i - 1] {
        b':' if i < 2 || b[i - 2] != b':' => b':',
        b'=' if i < 2 || !matches!(b[i - 2], b'=' | b'!' | b'<' | b'>') => b'=',
        _ => return None,
    };
    i -= 1;
    while i > 0 && b[i - 1].is_ascii_whitespace() {
        i -= 1;
    }
    ident_ending_at(line, i).map(|name| (name, intro))
}

/// Of `names`, the ones that also appear somewhere in `masked_lines`
/// with a *non-hash* `: Type` annotation (or struct-literal
/// initializer).  A name-based pass must drop those: `vertices` may be
/// a `HashSet` field on one struct and a `Vec` on another, and flagging
/// every `rg.vertices.iter()` would drown the signal.  Conservative by
/// design — an ambiguous name is silently untracked, which DESIGN.md
/// §11 lists as the price of a dependency-free lexical analysis.
pub fn ambiguous_names(
    masked_lines: &[String],
    names: &BTreeSet<String>,
) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for line in masked_lines {
        for name in names {
            if out.contains(name) {
                continue;
            }
            for pos in match_positions(line, name) {
                let b = line.as_bytes();
                // Ident-boundary occurrence followed by a single `:`.
                if pos > 0 && is_ident_char(b[pos - 1]) {
                    continue;
                }
                let mut i = pos + name.len();
                if i < b.len() && is_ident_char(b[i]) {
                    continue;
                }
                while i < b.len() && b[i].is_ascii_whitespace() {
                    i += 1;
                }
                if i >= b.len() || b[i] != b':' || b.get(i + 1) == Some(&b':') {
                    continue;
                }
                // The annotated type (or initializer expression): strip
                // references, `mut` and module paths, then ask whether a
                // hash collection remains.
                let mut ty = line[i + 1..].trim_start();
                loop {
                    if let Some(rest) = ty.strip_prefix('&') {
                        ty = rest.trim_start();
                    } else if let Some(rest) = ty.strip_prefix("mut ") {
                        ty = rest.trim_start();
                    } else {
                        break;
                    }
                }
                while let Some(sep) = ty.find("::") {
                    if ty[..sep].bytes().all(is_ident_char) {
                        ty = &ty[sep + 2..];
                    } else {
                        break;
                    }
                }
                if !ty.starts_with("HashMap") && !ty.starts_with("HashSet") {
                    out.insert(name.clone());
                }
            }
        }
    }
    out
}

fn match_positions(line: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = line[from..].find(needle) {
        out.push(from + p);
        from += p + needle.len();
    }
    out
}

/// Iteration adaptors whose visit order is the hash order.
const ITER_METHODS: [&str; 11] = [
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".drain(",
    ".into_iter()",
    ".into_keys()",
    ".into_values()",
    ".retain(",
    ".extract_if(",
];

/// DET-HASH-ITER: iterating a `HashMap`/`HashSet` in a module whose
/// event order or replay fingerprint the iteration can reach.  The fix
/// is a `BTreeMap`/`BTreeSet` or an explicit sort; genuinely
/// order-insensitive folds (counters, sums) may be suppressed *with a
/// reason*.  A statement that already sorts or collects into a BTree
/// container is exempt.
pub fn det_hash_iter(
    file: &SourceFile,
    local_names: &BTreeSet<String>,
    global_field_names: &BTreeSet<String>,
    findings: &mut Vec<Finding>,
) {
    if !in_det_scope(&file.path) {
        return;
    }
    for (idx, line) in file.masked.iter().enumerate() {
        if file.in_test_region(idx) {
            continue;
        }
        let mut hits: Vec<(usize, String)> = Vec::new();
        for m in ITER_METHODS {
            for pos in match_positions(line, m) {
                if let Some(seg) = ident_ending_at(line, pos) {
                    let dotted = pos > seg.len()
                        && line.as_bytes()[pos - seg.len() - 1] == b'.';
                    let local = local_names.contains(seg);
                    if local || (dotted && global_field_names.contains(seg)) {
                        hits.push((pos, seg.to_string()));
                    }
                }
            }
        }
        // `for x in map` / `for x in &map` without an adaptor call.
        if let Some(p) = line.find("for ") {
            if let Some(inp) = line[p..].find(" in ") {
                let expr = line[p + inp + 4..].trim_end().trim_end_matches('{').trim();
                let expr = expr.trim_start_matches('&').trim_start_matches("mut ").trim();
                if !expr.is_empty()
                    && expr.bytes().all(|c| is_ident_char(c) || c == b'.' || c == b':')
                {
                    let seg = expr.rsplit(['.', ':']).next().unwrap_or(expr);
                    let dotted = expr.contains('.');
                    if local_names.contains(seg)
                        || (dotted && global_field_names.contains(seg))
                    {
                        hits.push((p, seg.to_string()));
                    }
                }
            }
        }
        if hits.is_empty() {
            continue;
        }
        // Statement-level exemption: an adjacent sort or BTree collect
        // makes the order deterministic.
        let stmt = file.statement_at(idx);
        if stmt.contains("sort") || stmt.contains("BTree") {
            continue;
        }
        hits.sort();
        hits.dedup();
        for (_, name) in hits {
            findings.push(Finding::new(
                &file.path,
                idx as u32 + 1,
                DET_HASH_ITER,
                format!(
                    "iteration over hash-ordered collection `{name}` in a \
                     fingerprint-affecting module; use BTreeMap/BTreeSet or sort into a \
                     Vec first"
                ),
            ));
        }
    }
}

// ---------------------------------------------------------------------
// DET-WALLCLOCK
// ---------------------------------------------------------------------

const WALLCLOCK_TOKENS: [&str; 5] =
    ["SystemTime", "Instant::now", "thread_rng", "rand::random", "env::var"];

/// DET-WALLCLOCK: wall-clock reads, ambient randomness and environment
/// lookups inside simulation code break same-seed replay.  Virtual time
/// comes from `util::time`, randomness from the seeded `util::rng`.
pub fn det_wallclock(file: &SourceFile, findings: &mut Vec<Finding>) {
    if !in_det_scope(&file.path) {
        return;
    }
    for (idx, line) in file.masked.iter().enumerate() {
        if file.in_test_region(idx) {
            continue;
        }
        for tok in WALLCLOCK_TOKENS {
            if line.contains(tok) {
                findings.push(Finding::new(
                    &file.path,
                    idx as u32 + 1,
                    DET_WALLCLOCK,
                    format!(
                        "`{tok}` in simulation code: nondeterministic input breaks \
                         same-seed replay (use util::time / the seeded util::rng)"
                    ),
                ));
            }
        }
    }
}

// ---------------------------------------------------------------------
// EVT-UNWRAP-RATCHET
// ---------------------------------------------------------------------

/// Count of `.unwrap()` / `.expect(` occurrences on unsuppressed lines.
pub fn unwrap_counts(file: &SourceFile) -> Budget {
    let mut b = Budget::default();
    for (idx, line) in file.masked.iter().enumerate() {
        if file.suppressed(idx, EVT_UNWRAP_RATCHET) {
            continue;
        }
        b.unwrap += match_positions(line, ".unwrap()").len() as u64;
        b.expect += match_positions(line, ".expect(").len() as u64;
    }
    b
}

fn first_occurrence(file: &SourceFile, needle: &str) -> u32 {
    for (idx, line) in file.masked.iter().enumerate() {
        if !file.suppressed(idx, EVT_UNWRAP_RATCHET) && line.contains(needle) {
            return idx as u32 + 1;
        }
    }
    1
}

/// EVT-UNWRAP-RATCHET: event-path modules hold their panic-point debt
/// at or below the committed baseline.  Returns this file's live counts
/// so the caller can assemble the suggested (lowered) ratchet.
pub fn unwrap_ratchet(
    file: &SourceFile,
    baseline: &Ratchet,
    findings: &mut Vec<Finding>,
    suggestions: &mut Vec<String>,
) -> Option<(String, Budget)> {
    if !in_ratchet_scope(&file.path) {
        return None;
    }
    let key = file.path.trim_start_matches("src/").to_string();
    let live = unwrap_counts(file);
    let budget = baseline.get(&key).copied().unwrap_or_default();
    for (kind, live_n, budget_n, needle) in [
        ("unwrap", live.unwrap, budget.unwrap, ".unwrap()"),
        ("expect", live.expect, budget.expect, ".expect("),
    ] {
        if live_n > budget_n {
            findings.push(Finding::new(
                &file.path,
                first_occurrence(file, needle),
                EVT_UNWRAP_RATCHET,
                format!(
                    "`{needle}` count {live_n} exceeds the ratchet budget {budget_n} \
                     for {key}; propagate a typed SimError instead (the ratchet only \
                     goes down)"
                ),
            ));
        } else if live_n < budget_n {
            suggestions.push(format!(
                "ratchet for {key} may be lowered: {kind} {budget_n} -> {live_n} \
                 (run `nephele lint --update-ratchet`)"
            ));
        }
    }
    Some((key, live))
}

// ---------------------------------------------------------------------
// SHARD-LOCK
// ---------------------------------------------------------------------

/// SHARD-LOCK: in the sharded event core, (a) every `Mutex::lock()`
/// result must handle poisoning explicitly — `PoisonError::into_inner`,
/// a `match`/`if let` on the `Result` — or carry a reasoned
/// suppression; (b) a lock acquired inside a `for` loop (the cross-shard
/// outbox flush) must walk shards in ascending id order (an
/// `.enumerate()` run or a `0..n` range), the static counterpart of the
/// lock-ordering deadlock rule the ThreadSanitizer job checks
/// dynamically.
pub fn shard_lock(file: &SourceFile, findings: &mut Vec<Finding>) {
    if !is_shard_file(&file.path) {
        return;
    }
    for (idx, line) in file.masked.iter().enumerate() {
        if !line.contains(".lock()") {
            continue;
        }
        let stmt = file.statement_at(idx);
        let handled = (stmt.contains("unwrap_or_else") && stmt.contains("into_inner"))
            || stmt.trim_start().starts_with("match ")
            || stmt.contains("if let ");
        if !handled && !file.suppressed(idx, SHARD_LOCK) {
            findings.push(Finding::new(
                &file.path,
                idx as u32 + 1,
                SHARD_LOCK,
                "Mutex::lock() must handle poisoning (PoisonError::into_inner or an \
                 explicit match) — a peer shard's panic otherwise cascades as an \
                 unrelated lock panic"
                    .to_string(),
            ));
        }
        if let Some((for_idx, header)) = enclosing_for_header(file, idx) {
            let ascending = header.contains(".enumerate()") || header.contains("0..");
            if !ascending && !file.suppressed(idx, SHARD_LOCK) {
                findings.push(Finding::new(
                    &file.path,
                    for_idx as u32 + 1,
                    SHARD_LOCK,
                    "cross-shard locks inside a `for` loop must be acquired in \
                     ascending shard-id order (iterate with `.enumerate()` or a `0..` \
                     range) to keep the lock order total"
                        .to_string(),
                ));
            }
        }
    }
}

/// The nearest enclosing `for` header above `idx`, found by walking up
/// through strictly-shallower block openers (rustfmt indentation makes
/// openers shallower than their bodies).  Returns the header line index
/// and its text joined with up to two continuation lines, so a wrapped
/// `for x in\n  xs.iter().enumerate()` still exposes its iterator.
fn enclosing_for_header(file: &SourceFile, idx: usize) -> Option<(usize, String)> {
    let indent_of = |s: &str| s.len() - s.trim_start().len();
    let mut limit = indent_of(&file.masked[idx]);
    for j in (0..idx).rev() {
        let line = &file.masked[j];
        if line.trim().is_empty() {
            continue;
        }
        let ind = indent_of(line);
        if ind >= limit {
            continue;
        }
        let trimmed = line.trim_start();
        if trimmed.starts_with("fn ") || trimmed.contains(" fn ") {
            return None;
        }
        if trimmed.starts_with("for ") {
            let mut header = trimmed.to_string();
            for cont in file.masked.iter().skip(j + 1).take(2) {
                if header.trim_end().ends_with('{') {
                    break;
                }
                header.push(' ');
                header.push_str(cont.trim());
            }
            return Some((j, header));
        }
        limit = ind;
        if limit == 0 {
            return None;
        }
    }
    None
}
