//! The `nephele-lint` rules.
//!
//! All rules operate on *masked* source lines (string-literal interiors
//! and comments blanked by [`super::SourceFile`]), so trigger tokens
//! inside log messages or docs never fire.  The analysis is a
//! hand-rolled lexical scan — the offline build forbids `syn`/dylint —
//! which buys zero dependencies at the cost of being name-based rather
//! than type-based.  The four flow-aware rules at the bottom of this
//! file additionally consult the [`super::graph`] call-graph layer.
//! The escape hatch for the resulting (rare) false positives is an
//! explicit, reasoned `lint:allow` suppression; see `DESIGN.md` §11
//! and §13 for each rule's exact semantics and limits.

use super::graph::{CrateGraph, FileGraph};
use super::ratchet::{Budget, Ratchet};
use super::report::Finding;
use super::SourceFile;
use std::collections::{BTreeMap, BTreeSet};

/// Rule ids, stable across releases (reports, suppressions and fixtures
/// key on them).
pub const DET_HASH_ITER: &str = "DET-HASH-ITER";
pub const DET_WALLCLOCK: &str = "DET-WALLCLOCK";
pub const EVT_UNWRAP_RATCHET: &str = "EVT-UNWRAP-RATCHET";
pub const SHARD_LOCK: &str = "SHARD-LOCK";
pub const PANIC_REACH: &str = "PANIC-REACH";
pub const LOCK_CYCLE: &str = "LOCK-CYCLE";
pub const JOURNAL_COVERAGE: &str = "JOURNAL-COVERAGE";
pub const EVT_EXHAUSTIVE: &str = "EVT-EXHAUSTIVE";
/// Meta-rule for malformed suppressions; not itself suppressible.
pub const LINT_SUPPRESS: &str = "LINT-SUPPRESS";
/// Meta-rule for suppressions that suppress nothing; not suppressible.
pub const LINT_SUPPRESS_UNUSED: &str = "LINT-SUPPRESS-UNUSED";

pub const ALL_RULES: [&str; 8] = [
    DET_HASH_ITER,
    DET_WALLCLOCK,
    EVT_UNWRAP_RATCHET,
    SHARD_LOCK,
    PANIC_REACH,
    LOCK_CYCLE,
    JOURNAL_COVERAGE,
    EVT_EXHAUSTIVE,
];

/// Modules whose event order or fingerprints same-seed replay depends
/// on: the determinism rules apply here.  `src/telemetry/` is in scope
/// because the journal digest and metrics dump are replay fingerprints
/// themselves — a wall-clock read or hash-ordered render there breaks
/// the cross-thread digest guarantee just as surely as in the engine.
const DET_SCOPES: [&str; 5] =
    ["src/sim/", "src/sched/", "src/qos/", "src/actions/", "src/telemetry/"];

/// Modules under the unwrap ratchet: the whole crate.  The ratchet
/// started on the event path (`src/sim/`, `src/telemetry/`) and was
/// widened once the panic-path budgets landed — a ratchet that only
/// covers the modules that are already clean cannot burn down the debt
/// everywhere else.
const RATCHET_SCOPES: [&str; 1] = ["src/"];

pub fn in_det_scope(path: &str) -> bool {
    DET_SCOPES.iter().any(|s| path.starts_with(s))
}

pub fn in_ratchet_scope(path: &str) -> bool {
    RATCHET_SCOPES.iter().any(|s| path.starts_with(s))
}

pub fn is_shard_file(path: &str) -> bool {
    path.ends_with("sim/shard.rs")
}

fn is_ident_char(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// The identifier ending at byte `end` (exclusive), if any.
fn ident_ending_at(line: &str, end: usize) -> Option<&str> {
    let b = line.as_bytes();
    let mut start = end;
    while start > 0 && is_ident_char(b[start - 1]) {
        start -= 1;
    }
    if start == end || b[start].is_ascii_digit() {
        None
    } else {
        Some(&line[start..end])
    }
}

// ---------------------------------------------------------------------
// DET-HASH-ITER
// ---------------------------------------------------------------------

/// Collect names *declared* with a `HashMap`/`HashSet` type on a masked
/// line: struct fields, lets, params, struct-literal inits
/// (`name: HashMap<...>` / `name = std::collections::HashSet::new()`).
///
/// With `initializers` set, `=`-introduced bindings count too — that is
/// the per-file (local) mode.  Crate-wide the caller passes `false`, so
/// only `:`-annotated names (fields, typed lets) travel across files; a
/// field declared in `sim/task.rs` is then recognized when iterated as
/// `self.tasks[i].field.iter()` in `sim/worker.rs`, while short local
/// binding names cannot leak into other files' dotted accesses.
pub fn annotated_hash_names(masked_lines: &[String], initializers: bool) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for line in masked_lines {
        for needle in ["HashMap<", "HashSet<", "HashMap::", "HashSet::"] {
            for pos in match_positions(line, needle) {
                if let Some((name, intro)) = decl_name_before(line, pos) {
                    if (intro == b':' || initializers)
                        && !matches!(
                            name,
                            "mut" | "let" | "pub" | "crate" | "collections" | "std"
                        )
                    {
                        names.insert(name.to_string());
                    }
                }
            }
        }
    }
    names
}

/// The name being declared (or assigned) when a `Hash*` type token
/// starts at byte `pos`: walks back over an optional
/// `std::collections::` path to a `:` annotation or `=` initializer and
/// returns the identifier in front of it plus the introducer byte.
/// Return-type positions, tuple/turbofish contexts and `::` paths yield
/// `None`.
fn decl_name_before(line: &str, pos: usize) -> Option<(&str, u8)> {
    let b = line.as_bytes();
    let mut i = pos;
    while i > 0 && (is_ident_char(b[i - 1]) || b[i - 1] == b':') {
        i -= 1;
    }
    while i > 0 && b[i - 1].is_ascii_whitespace() {
        i -= 1;
    }
    if i == 0 {
        return None;
    }
    let intro = match b[i - 1] {
        b':' if i < 2 || b[i - 2] != b':' => b':',
        b'=' if i < 2 || !matches!(b[i - 2], b'=' | b'!' | b'<' | b'>') => b'=',
        _ => return None,
    };
    i -= 1;
    while i > 0 && b[i - 1].is_ascii_whitespace() {
        i -= 1;
    }
    ident_ending_at(line, i).map(|name| (name, intro))
}

/// Of `names`, the ones that also appear somewhere in `masked_lines`
/// with a *non-hash* `: Type` annotation (or struct-literal
/// initializer).  A name-based pass must drop those: `vertices` may be
/// a `HashSet` field on one struct and a `Vec` on another, and flagging
/// every `rg.vertices.iter()` would drown the signal.  Conservative by
/// design — an ambiguous name is silently untracked, which DESIGN.md
/// §11 lists as the price of a dependency-free lexical analysis.
pub fn ambiguous_names(
    masked_lines: &[String],
    names: &BTreeSet<String>,
) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for line in masked_lines {
        for name in names {
            if out.contains(name) {
                continue;
            }
            for pos in match_positions(line, name) {
                let b = line.as_bytes();
                // Ident-boundary occurrence followed by a single `:`.
                if pos > 0 && is_ident_char(b[pos - 1]) {
                    continue;
                }
                let mut i = pos + name.len();
                if i < b.len() && is_ident_char(b[i]) {
                    continue;
                }
                while i < b.len() && b[i].is_ascii_whitespace() {
                    i += 1;
                }
                if i >= b.len() || b[i] != b':' || b.get(i + 1) == Some(&b':') {
                    continue;
                }
                // The annotated type (or initializer expression): strip
                // references, `mut` and module paths, then ask whether a
                // hash collection remains.
                let mut ty = line[i + 1..].trim_start();
                loop {
                    if let Some(rest) = ty.strip_prefix('&') {
                        ty = rest.trim_start();
                    } else if let Some(rest) = ty.strip_prefix("mut ") {
                        ty = rest.trim_start();
                    } else {
                        break;
                    }
                }
                while let Some(sep) = ty.find("::") {
                    if ty[..sep].bytes().all(is_ident_char) {
                        ty = &ty[sep + 2..];
                    } else {
                        break;
                    }
                }
                if !ty.starts_with("HashMap") && !ty.starts_with("HashSet") {
                    out.insert(name.clone());
                }
            }
        }
    }
    out
}

fn match_positions(line: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = line[from..].find(needle) {
        out.push(from + p);
        from += p + needle.len();
    }
    out
}

/// Iteration adaptors whose visit order is the hash order.
const ITER_METHODS: [&str; 11] = [
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".drain(",
    ".into_iter()",
    ".into_keys()",
    ".into_values()",
    ".retain(",
    ".extract_if(",
];

/// DET-HASH-ITER: iterating a `HashMap`/`HashSet` in a module whose
/// event order or replay fingerprint the iteration can reach.  The fix
/// is a `BTreeMap`/`BTreeSet` or an explicit sort; genuinely
/// order-insensitive folds (counters, sums) may be suppressed *with a
/// reason*.  A statement that already sorts or collects into a BTree
/// container is exempt.
pub fn det_hash_iter(
    file: &SourceFile,
    local_names: &BTreeSet<String>,
    global_field_names: &BTreeSet<String>,
    findings: &mut Vec<Finding>,
) {
    if !in_det_scope(&file.path) {
        return;
    }
    for (idx, line) in file.masked.iter().enumerate() {
        if file.in_test_region(idx) {
            continue;
        }
        let mut hits: Vec<(usize, String)> = Vec::new();
        for m in ITER_METHODS {
            for pos in match_positions(line, m) {
                if let Some(seg) = ident_ending_at(line, pos) {
                    let dotted = pos > seg.len()
                        && line.as_bytes()[pos - seg.len() - 1] == b'.';
                    let local = local_names.contains(seg);
                    if local || (dotted && global_field_names.contains(seg)) {
                        hits.push((pos, seg.to_string()));
                    }
                }
            }
        }
        // `for x in map` / `for x in &map` without an adaptor call.
        if let Some(p) = line.find("for ") {
            if let Some(inp) = line[p..].find(" in ") {
                let expr = line[p + inp + 4..].trim_end().trim_end_matches('{').trim();
                let expr = expr.trim_start_matches('&').trim_start_matches("mut ").trim();
                if !expr.is_empty()
                    && expr.bytes().all(|c| is_ident_char(c) || c == b'.' || c == b':')
                {
                    let seg = expr.rsplit(['.', ':']).next().unwrap_or(expr);
                    let dotted = expr.contains('.');
                    if local_names.contains(seg)
                        || (dotted && global_field_names.contains(seg))
                    {
                        hits.push((p, seg.to_string()));
                    }
                }
            }
        }
        if hits.is_empty() {
            continue;
        }
        // Statement-level exemption: an adjacent sort or BTree collect
        // makes the order deterministic.
        let stmt = file.statement_at(idx);
        if stmt.contains("sort") || stmt.contains("BTree") {
            continue;
        }
        hits.sort();
        hits.dedup();
        for (_, name) in hits {
            findings.push(Finding::new(
                &file.path,
                idx as u32 + 1,
                DET_HASH_ITER,
                format!(
                    "iteration over hash-ordered collection `{name}` in a \
                     fingerprint-affecting module; use BTreeMap/BTreeSet or sort into a \
                     Vec first"
                ),
            ));
        }
    }
}

// ---------------------------------------------------------------------
// DET-WALLCLOCK
// ---------------------------------------------------------------------

const WALLCLOCK_TOKENS: [&str; 5] =
    ["SystemTime", "Instant::now", "thread_rng", "rand::random", "env::var"];

/// DET-WALLCLOCK: wall-clock reads, ambient randomness and environment
/// lookups inside simulation code break same-seed replay.  Virtual time
/// comes from `util::time`, randomness from the seeded `util::rng`.
pub fn det_wallclock(file: &SourceFile, findings: &mut Vec<Finding>) {
    if !in_det_scope(&file.path) {
        return;
    }
    for (idx, line) in file.masked.iter().enumerate() {
        if file.in_test_region(idx) {
            continue;
        }
        for tok in WALLCLOCK_TOKENS {
            if line.contains(tok) {
                findings.push(Finding::new(
                    &file.path,
                    idx as u32 + 1,
                    DET_WALLCLOCK,
                    format!(
                        "`{tok}` in simulation code: nondeterministic input breaks \
                         same-seed replay (use util::time / the seeded util::rng)"
                    ),
                ));
            }
        }
    }
}

// ---------------------------------------------------------------------
// EVT-UNWRAP-RATCHET
// ---------------------------------------------------------------------

/// Count of `.unwrap()` / `.expect(` occurrences on unsuppressed lines.
pub fn unwrap_counts(file: &SourceFile) -> Budget {
    let mut b = Budget::default();
    for (idx, line) in file.masked.iter().enumerate() {
        if file.suppressed(idx, EVT_UNWRAP_RATCHET) {
            continue;
        }
        b.unwrap += match_positions(line, ".unwrap()").len() as u64;
        b.expect += match_positions(line, ".expect(").len() as u64;
    }
    b
}

fn first_occurrence(file: &SourceFile, needle: &str) -> u32 {
    for (idx, line) in file.masked.iter().enumerate() {
        if !file.suppressed(idx, EVT_UNWRAP_RATCHET) && line.contains(needle) {
            return idx as u32 + 1;
        }
    }
    1
}

/// EVT-UNWRAP-RATCHET: event-path modules hold their panic-point debt
/// at or below the committed baseline.  Returns this file's live counts
/// so the caller can assemble the suggested (lowered) ratchet.
pub fn unwrap_ratchet(
    file: &SourceFile,
    baseline: &Ratchet,
    findings: &mut Vec<Finding>,
    suggestions: &mut Vec<String>,
) -> Option<(String, Budget)> {
    if !in_ratchet_scope(&file.path) {
        return None;
    }
    let key = file.path.trim_start_matches("src/").to_string();
    let live = unwrap_counts(file);
    let budget = baseline.files.get(&key).copied().unwrap_or_default();
    for (kind, live_n, budget_n, needle) in [
        ("unwrap", live.unwrap, budget.unwrap, ".unwrap()"),
        ("expect", live.expect, budget.expect, ".expect("),
    ] {
        if live_n > budget_n {
            findings.push(Finding::new(
                &file.path,
                first_occurrence(file, needle),
                EVT_UNWRAP_RATCHET,
                format!(
                    "`{needle}` count {live_n} exceeds the ratchet budget {budget_n} \
                     for {key}; propagate a typed SimError instead (the ratchet only \
                     goes down)"
                ),
            ));
        } else if live_n < budget_n {
            suggestions.push(format!(
                "ratchet for {key} may be lowered: {kind} {budget_n} -> {live_n} \
                 (run `nephele lint --update-ratchet`)"
            ));
        }
    }
    Some((key, live))
}

// ---------------------------------------------------------------------
// SHARD-LOCK
// ---------------------------------------------------------------------

/// SHARD-LOCK: in the sharded event core, (a) every `Mutex::lock()`
/// result must handle poisoning explicitly — `PoisonError::into_inner`,
/// a `match`/`if let` on the `Result` — or carry a reasoned
/// suppression; (b) a lock acquired inside a `for` loop (the cross-shard
/// outbox flush) must walk shards in ascending id order (an
/// `.enumerate()` run or a `0..n` range), the static counterpart of the
/// lock-ordering deadlock rule the ThreadSanitizer job checks
/// dynamically.
pub fn shard_lock(file: &SourceFile, findings: &mut Vec<Finding>) {
    if !is_shard_file(&file.path) {
        return;
    }
    for (idx, line) in file.masked.iter().enumerate() {
        if !line.contains(".lock()") {
            continue;
        }
        let stmt = file.statement_at(idx);
        let handled = (stmt.contains("unwrap_or_else") && stmt.contains("into_inner"))
            || stmt.trim_start().starts_with("match ")
            || stmt.contains("if let ");
        if !handled {
            findings.push(Finding::new(
                &file.path,
                idx as u32 + 1,
                SHARD_LOCK,
                "Mutex::lock() must handle poisoning (PoisonError::into_inner or an \
                 explicit match) — a peer shard's panic otherwise cascades as an \
                 unrelated lock panic"
                    .to_string(),
            ));
        }
        if let Some((for_idx, header)) = enclosing_for_header(file, idx) {
            let ascending = header.contains(".enumerate()") || header.contains("0..");
            if !ascending {
                findings.push(Finding::new(
                    &file.path,
                    for_idx as u32 + 1,
                    SHARD_LOCK,
                    "cross-shard locks inside a `for` loop must be acquired in \
                     ascending shard-id order (iterate with `.enumerate()` or a `0..` \
                     range) to keep the lock order total"
                        .to_string(),
                ));
            }
        }
    }
}

// ---------------------------------------------------------------------
// PANIC-REACH
// ---------------------------------------------------------------------

/// Event-dispatch roots whose transitive panic exposure is budgeted:
/// `(ratchet key, file, fn name)`.  The simulation dispatch loop, the
/// parallel shard driver, and every `main.rs` subcommand entry.
pub const PANIC_ROOTS: [(&str, &str, &str); 9] = [
    ("SimCluster::handle", "src/sim/cluster.rs", "handle"),
    ("ShardedEventCore::run_parallel", "src/sim/shard.rs", "run_parallel"),
    ("main::live", "src/main.rs", "live"),
    ("main::sim_failover", "src/main.rs", "sim_failover"),
    ("main::sim_meter", "src/main.rs", "sim_meter"),
    ("main::sim_multi", "src/main.rs", "sim_multi"),
    ("main::sim_scale", "src/main.rs", "sim_scale"),
    ("main::sim_surge", "src/main.rs", "sim_surge"),
    ("main::sim_video", "src/main.rs", "sim_video"),
];

/// PANIC-REACH: the number of panic sites (`.unwrap()`, `.expect(`,
/// panicking macros, slice indexing) transitively reachable from each
/// dispatch root stays at or below its committed budget.  Like the
/// unwrap ratchet this only goes down — but being call-graph-transitive
/// it also catches the case where an already-budgeted helper becomes
/// reachable from the event path for the first time.  Returns the live
/// per-root counts for ratchet assembly.
pub fn panic_reach(
    cg: &CrateGraph,
    files: &[SourceFile],
    baseline: &Ratchet,
    findings: &mut Vec<Finding>,
    suggestions: &mut Vec<String>,
) -> BTreeMap<String, u64> {
    let mut live = BTreeMap::new();
    for (key, path, name) in PANIC_ROOTS {
        let Some(root) = cg.fn_index(files, path, name) else { continue };
        let (seen, parent) = cg.reachable(root);
        let mut sites: Vec<(&str, usize, &'static str, usize)> = Vec::new();
        for (i, f) in cg.fns.iter().enumerate() {
            if !seen[i] {
                continue;
            }
            for &(line, tok) in &f.panics {
                sites.push((files[f.file].path.as_str(), line, tok, i));
            }
        }
        let count = sites.len() as u64;
        live.insert(key.to_string(), count);
        let budget = baseline.roots.get(key).copied().unwrap_or(0);
        if count > budget {
            sites.sort();
            let (spath, sline, stok, sfn) = sites[0];
            let mut chain = Vec::new();
            let mut cur = Some(sfn);
            while let Some(c) = cur {
                chain.push(cg.fns[c].key());
                cur = parent[c];
            }
            chain.reverse();
            findings.push(Finding::new(
                &files[cg.fns[root].file].path,
                cg.fns[root].line as u32 + 1,
                PANIC_REACH,
                format!(
                    "root {key} reaches {count} panic site(s), budget {budget}; \
                     e.g. {} -> {spath}:{} {stok}",
                    chain.join(" -> "),
                    sline + 1
                ),
            ));
        } else if count < budget {
            suggestions.push(format!(
                "panic-path budget for {key} may be lowered: reachable {budget} -> \
                 {count} (run `nephele lint --update-ratchet`)"
            ));
        }
    }
    live
}

// ---------------------------------------------------------------------
// LOCK-CYCLE
// ---------------------------------------------------------------------

/// LOCK-CYCLE: build the crate-wide lock-acquisition-order graph and
/// report any cycle.  While a lock is held — to the end of the function
/// for `let`-bound guards, to the end of the statement for temporaries —
/// every later lock acquired in the span, and every lock transitively
/// acquirable by a call in the span, becomes an ordered-after edge.
/// Locks are identified by receiver *name*, which deliberately merges
/// all elements of a lock array (`inboxes[i]` and `inboxes[j]` are one
/// node): per-element ordering within an array is exactly the discipline
/// SHARD-LOCK's ascending-id rule enforces, and merging is what lets the
/// rule see the classic AB/BA inversion between two arrays.
pub fn lock_cycle(cg: &CrateGraph, files: &[SourceFile], findings: &mut Vec<Finding>) {
    let trans = cg.locks_transitive();
    let mut ledges: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    // First acquisition site of each lock name, for anchoring findings.
    let mut sites: BTreeMap<&str, (&str, usize)> = BTreeMap::new();
    for f in &cg.fns {
        for l in &f.locks {
            let key = (files[f.file].path.as_str(), l.line);
            let e = sites.entry(l.name.as_str()).or_insert(key);
            if key < *e {
                *e = key;
            }
        }
    }
    for (i, f) in cg.fns.iter().enumerate() {
        if f.locks.is_empty() {
            continue;
        }
        for l in &f.locks {
            let span_end: Option<usize> = if l.guard {
                None
            } else {
                // Statement span: same <=5-line join as `statement_at`.
                let src = &files[f.file];
                let mut last = l.line;
                for k in l.line..(l.line + 5).min(src.masked.len()) {
                    last = k;
                    let t = src.masked[k].trim_end();
                    if t.ends_with(';') || t.ends_with('{') || t.ends_with('}') {
                        break;
                    }
                }
                Some(last)
            };
            let in_span =
                |at: usize| at > l.line && span_end.map_or(true, |e| at <= e);
            for l2 in &f.locks {
                if in_span(l2.line) {
                    ledges.entry(l.name.as_str()).or_default().insert(l2.name.as_str());
                }
            }
            for call in &f.calls {
                if !in_span(call.line) {
                    continue;
                }
                for t in cg.resolve_call(f, call) {
                    for n2 in &trans[t] {
                        ledges.entry(l.name.as_str()).or_default().insert(n2.as_str());
                    }
                }
            }
        }
    }
    let mut names: BTreeSet<&str> = ledges.keys().copied().collect();
    names.extend(ledges.values().flatten().copied());
    let mut reported: BTreeSet<Vec<&str>> = BTreeSet::new();
    for start in names {
        let Some(cyc) = find_cycle(&ledges, start) else { continue };
        let mut canon: Vec<&str> = cyc.iter().copied().collect::<BTreeSet<_>>()
            .into_iter().collect();
        canon.sort_unstable();
        if !reported.insert(canon.clone()) {
            continue;
        }
        let anchor = canon[0];
        let (path, line) = sites.get(anchor).copied().unwrap_or(("<unknown>", 0));
        let mut display = cyc.clone();
        display.push(cyc[0]);
        findings.push(Finding::new(
            path,
            line as u32 + 1,
            LOCK_CYCLE,
            format!(
                "lock-order cycle: {}; acquire in one global order or narrow the \
                 critical section",
                display.join(" -> ")
            ),
        ));
    }
}

/// DFS from `start` over the lock-order edges, looking for a path back
/// to `start`.  Neighbors are visited in descending name order (sorted
/// ascending, stack-popped), so the reported path is deterministic.
fn find_cycle<'a>(
    ledges: &BTreeMap<&'a str, BTreeSet<&'a str>>,
    start: &'a str,
) -> Option<Vec<&'a str>> {
    let mut stack: Vec<(&'a str, Vec<&'a str>)> = vec![(start, vec![start])];
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    while let Some((cur, path)) = stack.pop() {
        for &nxt in ledges.get(cur).into_iter().flatten() {
            if nxt == start {
                return Some(path);
            }
            if seen.insert(nxt) {
                let mut p = path.clone();
                p.push(nxt);
                stack.push((nxt, p));
            }
        }
    }
    None
}

// ---------------------------------------------------------------------
// JOURNAL-COVERAGE
// ---------------------------------------------------------------------

/// JOURNAL-COVERAGE: every function that mutates a decision counter
/// (`+=`/`-=` on a [`super::graph::DECISION_COUNTERS`] field) must
/// record a `TraceKind` — a `trace`/`trace_caused` call or a literal
/// `journal.append(` — in its own body or in a *direct* callee.  One
/// level of indirection covers the `bump-then-helper` shape without
/// letting a journal write three hops away excuse an unjournaled
/// decision.
pub fn journal_coverage(
    cg: &CrateGraph,
    files: &[SourceFile],
    findings: &mut Vec<Finding>,
) {
    let records: Vec<bool> = cg
        .fns
        .iter()
        .map(|f| {
            f.has_record
                || f.calls
                    .iter()
                    .any(|c| super::graph::RECORD_FNS.contains(&c.name.as_str()))
        })
        .collect();
    for (i, f) in cg.fns.iter().enumerate() {
        if f.mutations.is_empty() {
            continue;
        }
        if records[i] || cg.edges[i].iter().any(|&t| records[t]) {
            continue;
        }
        for &(line, counter) in &f.mutations {
            findings.push(Finding::new(
                &files[f.file].path,
                line as u32 + 1,
                JOURNAL_COVERAGE,
                format!(
                    "`{}` mutates decision counter `{counter}` but neither it nor a \
                     direct callee records a TraceKind; journal the decision so \
                     replay can reconstruct it",
                    f.key()
                ),
            ));
        }
    }
}

// ---------------------------------------------------------------------
// EVT-EXHAUSTIVE
// ---------------------------------------------------------------------

/// The dispatch enums whose `match`es must stay exhaustive, and the
/// modules where that is load-bearing (event core, scheduler,
/// telemetry).
pub const EXHAUSTIVE_ENUMS: [&str; 3] = ["Ev::", "Action::", "TraceKind::"];
const EXHAUSTIVE_SCOPES: [&str; 3] = ["src/sim/", "src/sched/", "src/telemetry/"];

/// EVT-EXHAUSTIVE: a wildcard `_` arm in a `match` over one of the
/// dispatch enums silently swallows every future variant — adding an
/// event kind should force each dispatch site to take a position, which
/// is the whole point of dispatching on an enum.  Guarded wildcards
/// (`_ if cond`) and binding patterns are not flagged; a `match` is "over"
/// an enum when any arm pattern starts with `Ev::`/`Action::`/`TraceKind::`.
pub fn evt_exhaustive(file: &SourceFile, fg: &FileGraph, findings: &mut Vec<Finding>) {
    if !EXHAUSTIVE_SCOPES.iter().any(|s| file.path.starts_with(s)) {
        return;
    }
    for m in &fg.matches {
        let Some(enum_name) = m.arms.iter().find_map(|(_, pat)| {
            let p = pat.trim_start_matches('|').trim_start();
            EXHAUSTIVE_ENUMS
                .iter()
                .find(|e| p.starts_with(**e))
                .map(|e| &e[..e.len() - 2])
        }) else {
            continue;
        };
        for (line, pat) in &m.arms {
            if pat.trim() == "_" {
                findings.push(Finding::new(
                    &file.path,
                    *line as u32 + 1,
                    EVT_EXHAUSTIVE,
                    format!(
                        "wildcard `_` arm in a `match` over `{enum_name}`: list the \
                         remaining variants explicitly so adding one forces this \
                         dispatch site to take a position"
                    ),
                ));
            }
        }
    }
}

/// The nearest enclosing `for` header above `idx`, found by walking up
/// through strictly-shallower block openers (rustfmt indentation makes
/// openers shallower than their bodies).  Returns the header line index
/// and its text joined with up to two continuation lines, so a wrapped
/// `for x in\n  xs.iter().enumerate()` still exposes its iterator.
fn enclosing_for_header(file: &SourceFile, idx: usize) -> Option<(usize, String)> {
    let indent_of = |s: &str| s.len() - s.trim_start().len();
    let mut limit = indent_of(&file.masked[idx]);
    for j in (0..idx).rev() {
        let line = &file.masked[j];
        if line.trim().is_empty() {
            continue;
        }
        let ind = indent_of(line);
        if ind >= limit {
            continue;
        }
        let trimmed = line.trim_start();
        if trimmed.starts_with("fn ") || trimmed.contains(" fn ") {
            return None;
        }
        if trimmed.starts_with("for ") {
            let mut header = trimmed.to_string();
            for cont in file.masked.iter().skip(j + 1).take(2) {
                if header.trim_end().ends_with('{') {
                    break;
                }
                header.push(' ');
                header.push_str(cont.trim());
            }
            return Some((j, header));
        }
        limit = ind;
        if limit == 0 {
            return None;
        }
    }
    None
}
