//! Findings and the deterministic machine-readable report.
//!
//! The report is consumed by CI and by the fixture self-tests, so its
//! rendering is fully deterministic: findings are sorted by
//! `(file, line, rule, message)` and both the text and JSON forms are
//! produced by hand (no formatter state, no hash iteration).

use std::fmt::Write as _;

/// One rule violation at a source location.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    /// Root-relative path with forward slashes (e.g. `src/sim/master.rs`).
    pub file: String,
    /// 1-based line number the finding anchors to.
    pub line: u32,
    /// Stable rule id (e.g. `DET-HASH-ITER`).
    pub rule: &'static str,
    pub message: String,
}

impl Finding {
    pub fn new(file: &str, line: u32, rule: &'static str, message: String) -> Finding {
        Finding { file: file.to_string(), line, rule, message }
    }
}

/// Outcome of one lint run over a tree.
#[derive(Debug, Default)]
pub struct LintReport {
    /// Violations, sorted by `(file, line, rule, message)`.
    pub findings: Vec<Finding>,
    /// Non-failing notes (e.g. "ratchet for X may be lowered to N"),
    /// sorted lexicographically.
    pub suggestions: Vec<String>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
}

impl LintReport {
    pub fn sort(&mut self) {
        self.findings.sort();
        self.findings.dedup();
        self.suggestions.sort();
    }

    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Stable line-oriented text form: one `RULE file:line message` per
    /// finding, then suggestions, then a summary line.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            let _ = writeln!(out, "{} {}:{} {}", f.rule, f.file, f.line, f.message);
        }
        for s in &self.suggestions {
            let _ = writeln!(out, "note: {s}");
        }
        let _ = writeln!(
            out,
            "nephele-lint: {} finding(s), {} suggestion(s), {} file(s) scanned",
            self.findings.len(),
            self.suggestions.len(),
            self.files_scanned
        );
        out
    }

    /// Stable JSON form (hand-rolled; the offline build has no serde).
    pub fn render_json(&self) -> String {
        let mut out = String::from("{\n  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            let sep = if i == 0 { "" } else { "," };
            let _ = write!(
                out,
                "{sep}\n    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \
                 \"message\": \"{}\"}}",
                escape_json(f.rule),
                escape_json(&f.file),
                f.line,
                escape_json(&f.message)
            );
        }
        if !self.findings.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("],\n  \"suggestions\": [");
        for (i, s) in self.suggestions.iter().enumerate() {
            let sep = if i == 0 { "" } else { "," };
            let _ = write!(out, "{sep}\n    \"{}\"", escape_json(s));
        }
        if !self.suggestions.is_empty() {
            out.push_str("\n  ");
        }
        let _ = write!(out, "],\n  \"files_scanned\": {}\n}}\n", self.files_scanned);
        out
    }
}

fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_rendering_is_sorted_and_stable() {
        let mut r = LintReport::default();
        r.findings.push(Finding::new("src/sim/b.rs", 9, "DET-WALLCLOCK", "x".into()));
        r.findings.push(Finding::new("src/sim/a.rs", 3, "DET-HASH-ITER", "y".into()));
        r.suggestions.push("zzz".into());
        r.suggestions.push("aaa".into());
        r.files_scanned = 2;
        r.sort();
        let text = r.render_text();
        let a = text.find("src/sim/a.rs:3").unwrap();
        let b = text.find("src/sim/b.rs:9").unwrap();
        assert!(a < b);
        assert!(text.find("note: aaa").unwrap() < text.find("note: zzz").unwrap());
        assert!(text.ends_with("2 file(s) scanned\n"));
        assert_eq!(text, r.render_text(), "rendering must be pure");
    }

    #[test]
    fn json_escapes_quotes_and_newlines() {
        let mut r = LintReport::default();
        r.findings.push(Finding::new("a.rs", 1, "DET-HASH-ITER", "say \"hi\"\n".into()));
        r.files_scanned = 1;
        let json = r.render_json();
        assert!(json.contains("say \\\"hi\\\"\\n"));
        assert!(json.contains("\"files_scanned\": 1"));
    }
}
