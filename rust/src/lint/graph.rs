//! The symbol/call-graph layer under the flow-aware lint rules.
//!
//! A dependency-free second pass over the masked source (the same
//! masked lines the lexical rules scan): per-file item extraction —
//! `fn` definitions with their `impl` qualifier, call sites, lock
//! acquisitions, panic sites, decision-counter mutations and enum
//! `match` blocks — followed by a crate-wide name-resolution pass with
//! deterministic `BTreeMap` ordering.
//!
//! Resolution is *name-based*, not type-based (the offline build forbids
//! `syn`), and deliberately conservative in both directions:
//!
//! * a dot call `.f()` resolves to **every** impl method named `f` in
//!   the crate (over-approximation: unrelated receivers merge),
//! * a qualified call `T::f()` resolves to the impl methods of `T`
//!   (with `Self` mapped to the enclosing impl target) and otherwise
//!   falls back to *free* functions named `f` — never to other types'
//!   methods, so `HashMap::new()` does not alias every `new` in the
//!   crate (under-approximation: unresolved externals vanish),
//! * a bare call `f()` resolves to free functions named `f` only.
//!
//! Known false-negative classes (documented in DESIGN.md §13): calls
//! through function pointers/closures passed as values, trait-object
//! dynamic dispatch, macro-generated code, and `use`-renamed imports.
//! Closure *bodies* are attributed to their enclosing `fn`, so panics
//! and locks inside them are still seen.

use super::SourceFile;
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Panicking macros, counted as panic sites alongside `.unwrap()`,
/// `.expect(` and slice/array indexing.
pub const PANIC_MACROS: [&str; 4] = ["panic!(", "unreachable!(", "todo!(", "unimplemented!("];

/// `SimStats` / `JobLedger` fields that record a control-plane
/// *decision* (as opposed to data-path flow counters): every `+=`/`-=`
/// on one of these must be journaled, or replay cannot reconstruct the
/// trajectory.  Kept sorted so reports are stable.
pub const DECISION_COUNTERS: [&str; 22] = [
    "admission_refreshes",
    "buffer_size_updates",
    "chains_established",
    "elastic_deferred",
    "failovers",
    "instances_detached",
    "instances_reassigned",
    "jobs_cancelled",
    "jobs_completed",
    "jobs_queued",
    "jobs_rejected",
    "jobs_submitted",
    "migrations",
    "preemptions",
    "qos_rebuilds",
    "scale_downs",
    "scale_ups",
    "scaling_rejected",
    "slots_preempted",
    "unresolvable",
    "unresolvable_notices",
    "workers_crashed",
];

/// Functions whose call marks the caller as journaling a `TraceKind`
/// (plus a literal `journal.append(` on the line).
pub const RECORD_FNS: [&str; 2] = ["trace", "trace_caused"];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CallKind {
    /// `f(...)` — resolves to free functions named `f`.
    Bare,
    /// `.f(...)` — resolves to every impl method named `f`.
    Dot,
    /// `Q::f(...)` — resolves to `Q`'s methods, else free `f`.
    Qual,
}

#[derive(Debug, Clone)]
pub struct Call {
    pub kind: CallKind,
    /// The `Q` of a qualified call.
    pub qual: Option<String>,
    pub name: String,
    /// 0-based line of the call site.
    pub line: usize,
}

#[derive(Debug, Clone)]
pub struct LockSite {
    /// 0-based line of the `.lock()` call.
    pub line: usize,
    /// Receiver identifier (`shards` in `self.shards[i].lock()`).
    pub name: String,
    /// `let`-bound guards are held to the end of the function;
    /// temporaries only to the end of their statement.
    pub guard: bool,
}

/// One extracted `fn` item with everything the flow rules consult.
#[derive(Debug, Clone)]
pub struct FnItem {
    /// Index into the scanned-files slice (files are path-sorted).
    pub file: usize,
    /// Bare name (`handle`).
    pub name: String,
    /// Enclosing `impl` target (`SimCluster`), if any.
    pub qual: Option<String>,
    /// 0-based line of the `fn` keyword.
    pub line: usize,
    pub calls: Vec<Call>,
    /// Panic sites: `(0-based line, token)`.
    pub panics: Vec<(usize, &'static str)>,
    pub locks: Vec<LockSite>,
    /// Decision-counter mutations: `(0-based line, counter)`.
    pub mutations: Vec<(usize, &'static str)>,
    /// A literal `journal.append(` appears in the body.
    pub has_record: bool,
}

impl FnItem {
    /// `SimCluster::handle` or `run_parallel`.
    pub fn key(&self) -> String {
        match &self.qual {
            Some(q) => format!("{q}::{}", self.name),
            None => self.name.clone(),
        }
    }
}

/// One `match` block whose arms sit at the block's own depth.
#[derive(Debug, Clone)]
pub struct MatchBlock {
    /// Arm lines: `(0-based line, pattern text before =>)`.
    pub arms: Vec<(usize, String)>,
}

/// Per-file extraction result.
#[derive(Debug, Clone, Default)]
pub struct FileGraph {
    pub fns: Vec<FnItem>,
    pub matches: Vec<MatchBlock>,
}

fn is_ident_char(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

fn ident_ending_at(line: &str, end: usize) -> Option<&str> {
    let b = line.as_bytes();
    let mut start = end;
    while start > 0 && is_ident_char(b[start - 1]) {
        start -= 1;
    }
    if start == end || b[start].is_ascii_digit() {
        None
    } else {
        Some(&line[start..end])
    }
}

fn match_positions(line: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = line[from..].find(needle) {
        out.push(from + p);
        from += p + needle.len();
    }
    out
}

/// Skip a leading `<...>` generics group, depth-counted; the `>` of a
/// `->` is not a closer.
fn strip_generics(s: &str) -> &str {
    if !s.starts_with('<') {
        return s;
    }
    let b = s.as_bytes();
    let mut depth = 0i32;
    let mut i = 0;
    while i < b.len() {
        if b[i] == b'<' {
            depth += 1;
        } else if b[i] == b'>' && (i == 0 || b[i - 1] != b'-') {
            depth -= 1;
            if depth == 0 {
                return &s[i + 1..];
            }
        }
        i += 1;
    }
    ""
}

/// `impl<E> Default for EventCore<E> {` → `EventCore`: the last path
/// segment of the type after `for` (or of the inherent-impl type).
fn impl_target(trimmed: &str) -> Option<String> {
    let rest = trimmed.strip_prefix("impl")?;
    let rest = strip_generics(rest.trim_start()).trim_start();
    let rest = match rest.find(" for ") {
        Some(p) => rest[p + 5..].trim_start(),
        None => rest,
    };
    let mut segs: Vec<String> = vec![String::new()];
    for &c in rest.as_bytes() {
        if is_ident_char(c) {
            segs.last_mut().expect("segs is never empty").push(c as char);
        } else if c == b':' {
            if !segs.last().expect("segs is never empty").is_empty() {
                segs.push(String::new());
            }
        } else {
            break;
        }
    }
    let name = match segs.last() {
        Some(last) if !last.is_empty() => last.clone(),
        _ if segs.len() > 1 => segs[segs.len() - 2].clone(),
        _ => String::new(),
    };
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

/// The name a `fn` keyword on this line declares, if any.
fn fn_def_on(line: &str) -> Option<String> {
    let b = line.as_bytes();
    for pos in match_positions(line, "fn ") {
        if pos > 0 && is_ident_char(b[pos - 1]) {
            continue;
        }
        let mut j = pos + 3;
        while j < b.len() && b[j] == b' ' {
            j += 1;
        }
        let start = j;
        while j < b.len() && is_ident_char(b[j]) {
            j += 1;
        }
        if j == start {
            continue;
        }
        if j < b.len() && (b[j] == b'(' || b[j] == b'<') {
            return Some(line[start..j].to_string());
        }
    }
    None
}

/// Receiver identifier of `X.lock()`: walks back over one or more
/// `[...]`/`(...)` groups (`self.inboxes[peer].lock()` → `inboxes`).
fn lock_name_before(line: &str, pos: usize) -> Option<&str> {
    let b = line.as_bytes();
    let mut i = pos;
    while i > 0 && (b[i - 1] == b')' || b[i - 1] == b']') {
        let close = b[i - 1];
        let opener = if close == b')' { b'(' } else { b'[' };
        let mut d = 0i32;
        let mut j = i as i64 - 1;
        while j >= 0 {
            let c = b[j as usize];
            if c == close {
                d += 1;
            } else if c == opener {
                d -= 1;
                if d == 0 {
                    break;
                }
            }
            j -= 1;
        }
        if j < 0 {
            return None;
        }
        i = j as usize;
    }
    ident_ending_at(line, i)
}

/// Count of panic-site tokens on one masked line (used both for
/// extraction and for deciding whether a `PANIC-REACH` suppression
/// suppresses anything).
pub fn panic_tokens_on(line: &str) -> Vec<&'static str> {
    let mut out = Vec::new();
    for tok in [".unwrap()", ".expect("] {
        for _ in match_positions(line, tok) {
            out.push(tok);
        }
    }
    for tok in PANIC_MACROS {
        for _ in match_positions(line, tok) {
            out.push(tok);
        }
    }
    let b = line.as_bytes();
    for pos in match_positions(line, "[") {
        if pos > 0 && (is_ident_char(b[pos - 1]) || b[pos - 1] == b')' || b[pos - 1] == b']') {
            out.push("indexing");
        }
    }
    out
}

/// Extract the item graph of one parsed file.  Test regions are
/// excluded wholesale: the graph serves production-path rules.
pub fn extract(file_idx: usize, src: &SourceFile) -> FileGraph {
    let mut g = FileGraph::default();
    let mut depth = 0i64;
    // (target, close_depth): the impl closes when its `}` is reached.
    let mut impl_stack: Vec<(String, i64)> = Vec::new();
    // (fn index, body_depth).
    let mut fn_stack: Vec<(usize, i64)> = Vec::new();
    let mut pending_fn: Option<usize> = None;
    let mut pending_impl: Option<String> = None;
    let mut pending_match = false;
    // (body_depth, arms).
    let mut match_stack: Vec<(i64, Vec<(usize, String)>)> = Vec::new();
    for (idx, line) in src.masked.iter().enumerate() {
        if src.in_test_region(idx) {
            break;
        }
        let trimmed = line.trim_start();
        if trimmed.starts_with("impl")
            && !trimmed.as_bytes().get(4).copied().is_some_and(is_ident_char)
        {
            if let Some(t) = impl_target(trimmed) {
                pending_impl = Some(t);
            }
        }
        if let Some(name) = fn_def_on(line) {
            let qual = if fn_stack.is_empty() {
                impl_stack.last().map(|(t, _)| t.clone())
            } else {
                None
            };
            g.fns.push(FnItem {
                file: file_idx,
                name,
                qual,
                line: idx,
                calls: Vec::new(),
                panics: Vec::new(),
                locks: Vec::new(),
                mutations: Vec::new(),
                has_record: false,
            });
            pending_fn = Some(g.fns.len() - 1);
        }
        let owner = pending_fn.or_else(|| fn_stack.last().map(|&(i, _)| i));
        for pos in match_positions(line, "match ") {
            if pos > 0 && is_ident_char(line.as_bytes()[pos - 1]) {
                continue;
            }
            pending_match = true;
            break;
        }
        if let Some((body_depth, arms)) = match_stack.last_mut() {
            if depth == *body_depth && line.contains("=>") {
                let pat = line.split("=>").next().unwrap_or("").trim().to_string();
                arms.push((idx, pat));
            }
        }
        for &c in line.as_bytes() {
            match c {
                b'{' => {
                    depth += 1;
                    if pending_match {
                        match_stack.push((depth, Vec::new()));
                        pending_match = false;
                    } else if let Some(f) = pending_fn.take() {
                        fn_stack.push((f, depth));
                    } else if let Some(t) = pending_impl.take() {
                        impl_stack.push((t, depth));
                    }
                }
                b'}' => {
                    while match_stack.last().is_some_and(|&(d, _)| d == depth) {
                        let (_, arms) = match_stack.pop().expect("checked non-empty");
                        g.matches.push(MatchBlock { arms });
                    }
                    if fn_stack.last().is_some_and(|&(_, d)| d == depth) {
                        fn_stack.pop();
                    }
                    if impl_stack.last().is_some_and(|&(_, d)| d == depth) {
                        impl_stack.pop();
                    }
                    depth -= 1;
                }
                b';' => {
                    // A bodiless declaration (trait method, extern fn)
                    // or a statement ending a pending match scrutinee.
                    pending_fn = None;
                    pending_match = false;
                }
                _ => {}
            }
        }
        if let Some(owner) = owner {
            scan_line(src, idx, line, &mut g.fns[owner]);
        }
    }
    g
}

/// Collect the per-line artifacts of `line` into its owning `fn`.
fn scan_line(src: &SourceFile, idx: usize, line: &str, f: &mut FnItem) {
    let b = line.as_bytes();
    // -- calls ----------------------------------------------------
    for pos in match_positions(line, "(") {
        let Some(name) = ident_ending_at(line, pos) else { continue };
        let start = pos - name.len();
        if start >= 3 && &line[start - 3..start] == "fn " {
            continue; // a definition, not a call
        }
        let prev = if start > 0 { b[start - 1] } else { 0 };
        if prev == b'.' {
            f.calls.push(Call {
                kind: CallKind::Dot,
                qual: None,
                name: name.to_string(),
                line: idx,
            });
        } else if prev == b':' && start >= 2 && b[start - 2] == b':' {
            if let Some(q) = ident_ending_at(line, start - 2) {
                f.calls.push(Call {
                    kind: CallKind::Qual,
                    qual: Some(q.to_string()),
                    name: name.to_string(),
                    line: idx,
                });
            }
        } else {
            f.calls.push(Call {
                kind: CallKind::Bare,
                qual: None,
                name: name.to_string(),
                line: idx,
            });
        }
    }
    // -- journal record sites -------------------------------------
    if line.contains("journal.append(") {
        f.has_record = true;
    }
    // -- panic sites ----------------------------------------------
    if !src.suppressed(idx, "PANIC-REACH") {
        for tok in panic_tokens_on(line) {
            f.panics.push((idx, tok));
        }
    }
    // -- lock sites -----------------------------------------------
    for pos in match_positions(line, ".lock()") {
        if let Some(name) = lock_name_before(line, pos) {
            let guard = line[..pos].contains("let ");
            f.locks.push(LockSite { line: idx, name: name.to_string(), guard });
        }
    }
    // -- decision-counter mutations -------------------------------
    if !line.contains("+=") && !line.contains("-=") {
        return;
    }
    for counter in DECISION_COUNTERS {
        for pos in match_positions(line, &format!(".{counter}")) {
            let mut j = pos + 1 + counter.len();
            if j < b.len() && is_ident_char(b[j]) {
                continue;
            }
            while j < b.len() && b[j] == b' ' {
                j += 1;
            }
            if matches!(line.get(j..j + 2), Some("+=") | Some("-=")) {
                f.mutations.push((idx, counter));
            }
        }
    }
}

/// The crate-wide resolved graph: every non-test `fn` in the tree plus
/// its resolved call edges (sorted, deduplicated).
pub struct CrateGraph {
    pub fns: Vec<FnItem>,
    pub edges: Vec<Vec<usize>>,
    by_bare: BTreeMap<String, Vec<usize>>,
    by_qual: BTreeMap<String, Vec<usize>>,
    free_by_name: BTreeMap<String, Vec<usize>>,
}

impl CrateGraph {
    pub fn build(graphs: &[FileGraph]) -> CrateGraph {
        let fns: Vec<FnItem> =
            graphs.iter().flat_map(|g| g.fns.iter().cloned()).collect();
        let mut by_bare: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        let mut by_qual: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        let mut free_by_name: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (i, f) in fns.iter().enumerate() {
            by_bare.entry(f.name.clone()).or_default().push(i);
            match &f.qual {
                Some(q) => by_qual.entry(format!("{q}::{}", f.name)).or_default().push(i),
                None => free_by_name.entry(f.name.clone()).or_default().push(i),
            }
        }
        let mut cg = CrateGraph { fns, edges: Vec::new(), by_bare, by_qual, free_by_name };
        cg.edges = cg
            .fns
            .iter()
            .map(|f| {
                let mut out = BTreeSet::new();
                for call in &f.calls {
                    out.extend(cg.resolve_call(f, call));
                }
                out.into_iter().collect()
            })
            .collect();
        cg
    }

    /// Targets of one call site (see the module docs for the rules).
    pub fn resolve_call(&self, from: &FnItem, call: &Call) -> Vec<usize> {
        match call.kind {
            CallKind::Dot => self
                .by_bare
                .get(&call.name)
                .map(|v| {
                    v.iter().copied().filter(|&i| self.fns[i].qual.is_some()).collect()
                })
                .unwrap_or_default(),
            CallKind::Qual => {
                let mut q = call.qual.clone().unwrap_or_default();
                if q == "Self" {
                    if let Some(fq) = &from.qual {
                        q = fq.clone();
                    }
                }
                match self.by_qual.get(&format!("{q}::{}", call.name)) {
                    Some(v) => v.clone(),
                    None => self.free_by_name.get(&call.name).cloned().unwrap_or_default(),
                }
            }
            CallKind::Bare => self.free_by_name.get(&call.name).cloned().unwrap_or_default(),
        }
    }

    /// The first `fn` named `name` in file `path`, if any.
    pub fn fn_index(&self, files: &[SourceFile], path: &str, name: &str) -> Option<usize> {
        self.fns
            .iter()
            .position(|f| files[f.file].path == path && f.name == name)
    }

    /// BFS over call edges from `root`: the reachable set plus a parent
    /// map for reconstructing one call chain per reached `fn`.
    pub fn reachable(&self, root: usize) -> (Vec<bool>, Vec<Option<usize>>) {
        let mut seen = vec![false; self.fns.len()];
        let mut parent = vec![None; self.fns.len()];
        let mut queue = VecDeque::new();
        seen[root] = true;
        queue.push_back(root);
        while let Some(cur) = queue.pop_front() {
            for &t in &self.edges[cur] {
                if !seen[t] {
                    seen[t] = true;
                    parent[t] = Some(cur);
                    queue.push_back(t);
                }
            }
        }
        (seen, parent)
    }

    /// Per-`fn` transitive lock set: every lock name the function or
    /// any (transitive) callee may acquire.  Cycles contribute what was
    /// gathered before the back-edge — the same conservative cut both
    /// the mirror and the rule documentation describe.
    pub fn locks_transitive(&self) -> Vec<BTreeSet<String>> {
        let mut memo: Vec<Option<BTreeSet<String>>> = vec![None; self.fns.len()];
        let mut stack = vec![false; self.fns.len()];
        for i in 0..self.fns.len() {
            self.locks_go(i, &mut memo, &mut stack);
        }
        memo.into_iter().map(|m| m.unwrap_or_default()).collect()
    }

    fn locks_go(
        &self,
        i: usize,
        memo: &mut Vec<Option<BTreeSet<String>>>,
        stack: &mut Vec<bool>,
    ) -> BTreeSet<String> {
        if let Some(m) = &memo[i] {
            return m.clone();
        }
        if stack[i] {
            return BTreeSet::new();
        }
        stack[i] = true;
        let mut out: BTreeSet<String> =
            self.fns[i].locks.iter().map(|l| l.name.clone()).collect();
        for t in self.edges[i].clone() {
            out.extend(self.locks_go(t, memo, stack));
        }
        stack[i] = false;
        memo[i] = Some(out.clone());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> SourceFile {
        SourceFile::parse("src/sim/x.rs".to_string(), text)
    }

    #[test]
    fn fn_defs_get_their_impl_qualifier() {
        let f = parse(
            "pub struct A;\nimpl A {\n    pub fn m(&self) {}\n}\nimpl Default for A {\n    fn default() -> A { A }\n}\nfn free() {}\n",
        );
        let g = extract(0, &f);
        let keys: Vec<String> = g.fns.iter().map(|f| f.key()).collect();
        assert_eq!(keys, vec!["A::m", "A::default", "free"]);
    }

    #[test]
    fn calls_classify_as_bare_dot_and_qualified() {
        let f = parse("fn a() {\n    helper();\n    self.m();\n    Shard::go();\n}\n");
        let g = extract(0, &f);
        let kinds: Vec<(CallKind, &str)> =
            g.fns[0].calls.iter().map(|c| (c.kind, c.name.as_str())).collect();
        assert_eq!(
            kinds,
            vec![
                (CallKind::Bare, "helper"),
                (CallKind::Dot, "m"),
                (CallKind::Qual, "go")
            ]
        );
    }

    #[test]
    fn panic_sites_include_indexing_but_not_attributes() {
        let f = parse(
            "fn a(xs: &[u32]) -> u32 {\n    #[allow(dead_code)]\n    let v = vec![1];\n    xs[0] + v[0]\n}\n",
        );
        let g = extract(0, &f);
        assert_eq!(g.fns[0].panics.len(), 2, "two index sites: {:?}", g.fns[0].panics);
    }

    #[test]
    fn closure_bodies_attribute_to_the_enclosing_fn() {
        let f = parse("fn a(xs: &[u32]) -> u32 {\n    xs.iter().map(|x| x + other(*x)).sum()\n}\nfn other(x: u32) -> u32 { x }\n");
        let g = extract(0, &f);
        assert!(g.fns[0].calls.iter().any(|c| c.name == "other"));
    }

    #[test]
    fn qualified_calls_do_not_alias_foreign_methods() {
        let f = parse(
            "pub struct A;\nimpl A {\n    pub fn new() -> A { A }\n}\nfn mk() {\n    let _ = std::collections::HashMap::<u32, u32>::new();\n    let _ = A::new();\n}\n",
        );
        let g = extract(0, &f);
        let cg = CrateGraph::build(&[g]);
        let mk = cg.fns.iter().position(|f| f.name == "mk").expect("mk extracted");
        assert_eq!(cg.edges[mk].len(), 1, "only A::new resolves: {:?}", cg.edges[mk]);
    }

    #[test]
    fn match_blocks_collect_their_arms() {
        let f = parse(
            "enum E { A, B }\nfn d(e: &E) -> u32 {\n    match e {\n        E::A => 1,\n        _ => 0,\n    }\n}\n",
        );
        let g = extract(0, &f);
        assert_eq!(g.matches.len(), 1);
        let arms: Vec<&str> = g.matches[0].arms.iter().map(|(_, p)| p.as_str()).collect();
        assert_eq!(arms, vec!["E::A", "_"]);
    }

    #[test]
    fn test_regions_are_outside_the_graph() {
        let f = parse("fn a() {}\n#[cfg(test)]\nmod tests {\n    fn t() { panic!(\"x\") }\n}\n");
        let g = extract(0, &f);
        assert_eq!(g.fns.len(), 1);
        assert_eq!(g.fns[0].name, "a");
    }

    #[test]
    fn guard_locks_differ_from_temporaries() {
        let f = parse(
            "fn a(m: &std::sync::Mutex<u32>) {\n    let g = m.lock().unwrap_or_else(std::sync::PoisonError::into_inner);\n    drop(g);\n    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner);\n}\n",
        );
        let g = extract(0, &f);
        assert_eq!(g.fns[0].locks.len(), 2);
        assert!(g.fns[0].locks[0].guard);
        assert!(!g.fns[0].locks[1].guard);
    }

    #[test]
    fn decision_counter_mutations_require_a_compound_assignment() {
        let f = parse(
            "fn a(s: &mut S) {\n    s.scale_ups += 1;\n    s.jobs_submitted = 1;\n    s.scale_ups_total += 1;\n}\n",
        );
        let g = extract(0, &f);
        assert_eq!(g.fns[0].mutations, vec![(1, "scale_ups")]);
    }
}
