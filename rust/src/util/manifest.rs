//! Parser for `artifacts/manifest.txt`, the line-oriented twin of
//! `manifest.json` emitted by `python/compile/aot.py`:
//!
//! ```text
//! frame 240 320
//! stage decoder decoder.hlo.txt 240x320
//! stage overlay overlay.hlo.txt 480x640,480x640,480x640
//! ```

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One AOT-compiled stage: HLO file + input shapes (f32 everywhere).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageSpec {
    pub name: String,
    pub file: PathBuf,
    pub input_shapes: Vec<Vec<usize>>,
}

impl StageSpec {
    /// Total number of f32 elements across all inputs.
    pub fn input_elems(&self) -> usize {
        self.input_shapes.iter().map(|s| s.iter().product::<usize>()).sum()
    }
}

/// Parsed artifact manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    pub frame_h: usize,
    pub frame_w: usize,
    pub stages: BTreeMap<String, StageSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`; stage file paths are resolved against
    /// `dir`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut frame = None;
        let mut stages = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            match it.next() {
                Some("frame") => {
                    let h = parse_num(it.next(), lineno, "frame height")?;
                    let w = parse_num(it.next(), lineno, "frame width")?;
                    frame = Some((h, w));
                }
                Some("stage") => {
                    let name = it.next().context("stage name missing")?.to_string();
                    let file = it.next().context("stage file missing")?;
                    let shapes_str = it.next().context("stage shapes missing")?;
                    let input_shapes = shapes_str
                        .split(',')
                        .map(|s| {
                            s.split('x')
                                .map(|d| d.parse::<usize>().map_err(Into::into))
                                .collect::<Result<Vec<usize>>>()
                        })
                        .collect::<Result<Vec<_>>>()
                        .with_context(|| format!("line {}: bad shapes {shapes_str}", lineno + 1))?;
                    stages.insert(
                        name.clone(),
                        StageSpec { name, file: dir.join(file), input_shapes },
                    );
                }
                Some(other) => bail!("line {}: unknown directive {other:?}", lineno + 1),
                None => {}
            }
        }
        let (frame_h, frame_w) = frame.context("manifest missing `frame` line")?;
        if stages.is_empty() {
            bail!("manifest has no stages");
        }
        Ok(Manifest { frame_h, frame_w, stages })
    }

    pub fn stage(&self, name: &str) -> Result<&StageSpec> {
        self.stages
            .get(name)
            .with_context(|| format!("stage {name:?} not in manifest"))
    }
}

fn parse_num(tok: Option<&str>, lineno: usize, what: &str) -> Result<usize> {
    tok.with_context(|| format!("line {}: {what} missing", lineno + 1))?
        .parse()
        .with_context(|| format!("line {}: {what} not a number", lineno + 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
frame 240 320
stage decoder decoder.hlo.txt 240x320
stage overlay overlay.hlo.txt 480x640,480x640,480x640
";

    #[test]
    fn parses_frame_and_stages() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!((m.frame_h, m.frame_w), (240, 320));
        assert_eq!(m.stages.len(), 2);
        let ov = m.stage("overlay").unwrap();
        assert_eq!(ov.input_shapes.len(), 3);
        assert_eq!(ov.input_shapes[0], vec![480, 640]);
        assert_eq!(ov.file, Path::new("/a/overlay.hlo.txt"));
        assert_eq!(ov.input_elems(), 3 * 480 * 640);
    }

    #[test]
    fn rejects_missing_frame() {
        assert!(Manifest::parse("stage a a.hlo.txt 8x8\n", Path::new(".")).is_err());
    }

    #[test]
    fn rejects_unknown_directive() {
        assert!(Manifest::parse("frame 8 8\nbogus x\n", Path::new(".")).is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(Manifest::parse("frame 8 8\n", Path::new(".")).is_err());
    }

    #[test]
    fn ignores_comments_and_blanks() {
        let m = Manifest::parse("# hi\n\nframe 8 8\nstage d d.hlo.txt 8x8\n", Path::new("."))
            .unwrap();
        assert_eq!(m.stages.len(), 1);
    }
}
