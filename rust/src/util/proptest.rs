//! Minimal property-testing harness (no external `proptest` available in
//! the offline build).
//!
//! Usage:
//!
//! ```ignore
//! check(100, |g| {
//!     let n = g.usize(1..=50);
//!     let v = g.vec(n, |g| g.u64(0..=100));
//!     prop_assert(v.len() == n, "len mismatch")
//! });
//! ```
//!
//! Each case runs with a deterministic seed derived from the case index;
//! on failure the harness panics with the failing seed so the case can be
//! replayed with [`check_seed`].

use super::rng::Rng;
use std::ops::RangeInclusive;

/// Generator handle passed to property closures.
pub struct Gen {
    rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen { rng: Rng::new(seed), seed }
    }

    pub fn u64(&mut self, r: RangeInclusive<u64>) -> u64 {
        self.rng.range(*r.start(), *r.end())
    }

    pub fn usize(&mut self, r: RangeInclusive<usize>) -> usize {
        self.rng.range(*r.start() as u64, *r.end() as u64) as usize
    }

    pub fn u32(&mut self, r: RangeInclusive<u32>) -> u32 {
        self.rng.range(*r.start() as u64, *r.end() as u64) as u32
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.f64_range(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    pub fn vec<T>(&mut self, n: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..n).map(|_| f(self)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        self.rng.shuffle(xs)
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Outcome of one property case: `Err` carries the failure message.
pub type PropResult = Result<(), String>;

/// Assert helper that returns instead of panicking, so the harness can
/// attach the seed.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn prop_assert_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T, ctx: &str) -> PropResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("{ctx}: {a:?} != {b:?}"))
    }
}

/// Run `cases` property cases with seeds `0..cases` (xor a fixed salt).
/// Panics with the failing seed + message on the first failure.
pub fn check(cases: u64, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    for i in 0..cases {
        let seed = i ^ 0x5EED_0000;
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!("property failed (replay with check_seed({seed:#x}, ..)): {msg}");
        }
    }
}

/// Replay a single failing case.
pub fn check_seed(seed: u64, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    let mut g = Gen::new(seed);
    if let Err(msg) = prop(&mut g) {
        panic!("property failed for seed {seed:#x}: {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check(25, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_panics_with_seed_on_failure() {
        check(10, |g| prop_assert(g.u64(0..=10) > 100, "always fails"));
    }

    #[test]
    fn generators_respect_ranges() {
        check(50, |g| {
            let n = g.usize(1..=8);
            let v = g.vec(n, |g| g.u64(5..=9));
            prop_assert(v.len() == n && v.iter().all(|&x| (5..=9).contains(&x)), "range")
        });
    }
}
