//! Statistics primitives used by the QoS machinery (§3.3 of the paper).
//!
//! * [`RunningAvg`] — plain online mean (report pre-aggregation on the
//!   QoS Reporter side).
//! * [`WindowAvg`] — running average over measurements *fresher than t
//!   time units*: the manager-side estimator from §3.3 ("it will keep all
//!   latency measurement data ... fresher than t time units and discard
//!   all older measurement data").
//! * [`Summary`] — min/mean/max/percentile reporting for experiment
//!   harnesses (the dot-dashed min/max lines of Figs. 7–10).

use super::time::{Duration, Time};
use std::collections::VecDeque;

/// Plain online arithmetic mean with a sample count.
#[derive(Debug, Clone, Default)]
pub struct RunningAvg {
    sum: f64,
    n: u64,
}

impl RunningAvg {
    pub fn new() -> RunningAvg {
        RunningAvg::default()
    }
    pub fn add(&mut self, v: f64) {
        self.sum += v;
        self.n += 1;
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> Option<f64> {
        (self.n > 0).then(|| self.sum / self.n as f64)
    }
    pub fn take(&mut self) -> Option<(f64, u64)> {
        let out = self.mean().map(|m| (m, self.n));
        *self = RunningAvg::default();
        out
    }
}

/// Time-windowed running average: values older than the window are
/// discarded on insertion and query.  Weighted by sample count so that a
/// pre-aggregated report entry (mean of k samples) counts as k samples.
#[derive(Debug, Clone)]
pub struct WindowAvg {
    window: Duration,
    entries: VecDeque<(Time, f64, u64)>,
    sum: f64,
    weight: u64,
}

impl WindowAvg {
    pub fn new(window: Duration) -> WindowAvg {
        WindowAvg { window, entries: VecDeque::new(), sum: 0.0, weight: 0 }
    }

    pub fn window(&self) -> Duration {
        self.window
    }

    /// Insert a (possibly pre-aggregated) measurement taken at `at`.
    pub fn add(&mut self, at: Time, mean: f64, count: u64) {
        self.entries.push_back((at, mean, count));
        self.sum += mean * count as f64;
        self.weight += count;
        self.evict(at);
    }

    fn evict(&mut self, now: Time) {
        let cutoff = cutoff_time(now, self.window);
        while let Some(&(t, m, c)) = self.entries.front() {
            if t < cutoff {
                self.sum -= m * c as f64;
                self.weight -= c;
                self.entries.pop_front();
            } else {
                break;
            }
        }
    }

    /// Running average over samples fresher than the window at `now`.
    pub fn mean(&mut self, now: Time) -> Option<f64> {
        self.evict(now);
        (self.weight > 0).then(|| self.sum / self.weight as f64)
    }

    pub fn sample_count(&mut self, now: Time) -> u64 {
        self.evict(now);
        self.weight
    }

    /// Drop everything (used after a buffer-size change: "the QoS Manager
    /// waits until all latency measurement values based on the old buffer
    /// sizes have been flushed out", §3.5).
    pub fn clear(&mut self) {
        self.entries.clear();
        self.sum = 0.0;
        self.weight = 0;
    }

    /// Timestamp of the freshest sample, if any.
    pub fn latest(&self) -> Option<Time> {
        self.entries.back().map(|&(t, _, _)| t)
    }
}

fn cutoff_time(now: Time, window: Duration) -> Time {
    Time(now.0.saturating_sub(window.0))
}

/// Batch summary of a series (for experiment output).
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(values: &[f64]) -> Option<Summary> {
        if values.is_empty() {
            return None;
        }
        let mut sorted: Vec<f64> = values.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| sorted[(((sorted.len() - 1) as f64) * p).round() as usize];
        Some(Summary {
            n: sorted.len(),
            min: sorted[0],
            max: *sorted.last().unwrap(),
            mean: sorted.iter().sum::<f64>() / sorted.len() as f64,
            p50: pct(0.5),
            p99: pct(0.99),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_avg_mean() {
        let mut a = RunningAvg::new();
        assert_eq!(a.mean(), None);
        a.add(1.0);
        a.add(3.0);
        assert_eq!(a.mean(), Some(2.0));
        assert_eq!(a.take(), Some((2.0, 2)));
        assert_eq!(a.mean(), None);
    }

    #[test]
    fn window_avg_discards_stale() {
        let mut w = WindowAvg::new(Duration::from_secs(15));
        w.add(Time::from_secs_f64(0.0), 100.0, 1);
        w.add(Time::from_secs_f64(10.0), 200.0, 1);
        assert_eq!(w.mean(Time::from_secs_f64(10.0)), Some(150.0));
        // At t=20s the first sample (age 20s) is stale, second (10s) is not.
        assert_eq!(w.mean(Time::from_secs_f64(20.0)), Some(200.0));
        // At t=30s everything is stale.
        assert_eq!(w.mean(Time::from_secs_f64(30.0)), None);
    }

    #[test]
    fn window_avg_weights_preaggregated_reports() {
        let mut w = WindowAvg::new(Duration::from_secs(15));
        w.add(Time(0), 10.0, 9); // mean of 9 samples
        w.add(Time(1), 20.0, 1);
        assert_eq!(w.mean(Time(1)), Some(11.0));
    }

    #[test]
    fn window_avg_clear() {
        let mut w = WindowAvg::new(Duration::from_secs(1));
        w.add(Time(0), 5.0, 1);
        w.clear();
        assert_eq!(w.mean(Time(0)), None);
    }

    #[test]
    fn summary_percentiles() {
        let v: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let s = Summary::of(&v).unwrap();
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.mean, 50.5);
        assert_eq!(s.p50, 51.0); // index round(99*0.5)=50 -> value 51
        assert_eq!(s.p99, 99.0);
        assert!(Summary::of(&[]).is_none());
    }
}
