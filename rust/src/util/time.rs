//! Virtual time for the discrete-event simulator and shared latency math.
//!
//! All engine latencies — task latency, channel latency, output buffer
//! lifetime (§3.3 of the paper) — are carried as [`Duration`]s;
//! timestamps (tag creation times, report deadlines) as [`Time`].
//! Resolution is one microsecond, which is far below the paper's
//! millisecond-scale measurements and the <2 ms NTP skew of its testbed.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// An absolute point in (virtual or wall) time, in microseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Time(pub u64);

/// A span of time, in microseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Duration(pub u64);

impl Time {
    pub const ZERO: Time = Time(0);

    pub fn from_secs_f64(s: f64) -> Time {
        Time((s * 1e6) as u64)
    }
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }
    pub fn as_millis_f64(self) -> f64 {
        self.0 as f64 / 1e3
    }
    /// Saturating difference: `self - earlier`, zero if `earlier` is later.
    pub fn since(self, earlier: Time) -> Duration {
        Duration(self.0.saturating_sub(earlier.0))
    }
}

impl Duration {
    pub const ZERO: Duration = Duration(0);

    pub fn from_micros(us: u64) -> Duration {
        Duration(us)
    }
    pub fn from_millis(ms: u64) -> Duration {
        Duration(ms * 1_000)
    }
    pub fn from_secs(s: u64) -> Duration {
        Duration(s * 1_000_000)
    }
    pub fn from_secs_f64(s: f64) -> Duration {
        Duration((s.max(0.0) * 1e6).round() as u64)
    }
    pub fn as_micros(self) -> u64 {
        self.0
    }
    pub fn as_millis_f64(self) -> f64 {
        self.0 as f64 / 1e3
    }
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }
    pub fn mul_f64(self, k: f64) -> Duration {
        Duration::from_secs_f64(self.as_secs_f64() * k)
    }
    pub fn min(self, other: Duration) -> Duration {
        Duration(self.0.min(other.0))
    }
    pub fn max(self, other: Duration) -> Duration {
        Duration(self.0.max(other.0))
    }
}

impl Add<Duration> for Time {
    type Output = Time;
    fn add(self, d: Duration) -> Time {
        Time(self.0 + d.0)
    }
}

impl AddAssign<Duration> for Time {
    fn add_assign(&mut self, d: Duration) {
        self.0 += d.0;
    }
}

impl Sub<Time> for Time {
    type Output = Duration;
    fn sub(self, other: Time) -> Duration {
        Duration(self.0.saturating_sub(other.0))
    }
}

impl Add for Duration {
    type Output = Duration;
    fn add(self, other: Duration) -> Duration {
        Duration(self.0 + other.0)
    }
}

impl AddAssign for Duration {
    fn add_assign(&mut self, other: Duration) {
        self.0 += other.0;
    }
}

impl Sub for Duration {
    type Output = Duration;
    fn sub(self, other: Duration) -> Duration {
        Duration(self.0.saturating_sub(other.0))
    }
}

impl fmt::Display for Time {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}s", self.as_secs_f64())
    }
}

impl fmt::Display for Duration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 1_000_000 {
            write!(f, "{:.2}s", self.as_secs_f64())
        } else if self.0 >= 1_000 {
            write!(f, "{:.2}ms", self.as_millis_f64())
        } else {
            write!(f, "{}us", self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_roundtrips() {
        let t = Time::ZERO + Duration::from_millis(5) + Duration::from_micros(250);
        assert_eq!(t.0, 5_250);
        assert_eq!((t - Time::ZERO).as_millis_f64(), 5.25);
    }

    #[test]
    fn since_saturates() {
        assert_eq!(Time(5).since(Time(10)), Duration::ZERO);
        assert_eq!(Time(10).since(Time(5)), Duration(5));
    }

    #[test]
    fn display_units() {
        assert_eq!(Duration::from_secs(2).to_string(), "2.00s");
        assert_eq!(Duration::from_millis(3).to_string(), "3.00ms");
        assert_eq!(Duration::from_micros(7).to_string(), "7us");
    }

    #[test]
    fn mul_f64_rounds() {
        assert_eq!(Duration::from_millis(100).mul_f64(0.5), Duration::from_millis(50));
    }
}
