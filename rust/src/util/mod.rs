//! Small self-contained utilities: virtual time, deterministic PRNG,
//! windowed statistics and a dependency-free property-testing helper.
//!
//! The build environment resolves crates offline (see DESIGN.md), so the
//! usual suspects (`rand`, `proptest`, `serde`) are replaced by the
//! minimal implementations in this module.

pub mod manifest;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod time;

pub use rng::Rng;
pub use stats::{RunningAvg, WindowAvg};
pub use time::{Duration, Time};
