//! Deterministic PRNG (splitmix64 + xoshiro256**) for workload generation
//! and the property-test harness.  Reproducibility matters more than
//! cryptographic quality here: every experiment binary takes a `--seed`.

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-entity RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`; `n` must be non-zero.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough reduction is fine
        // for simulation purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        match (hi - lo).checked_add(1) {
            Some(span) => lo + self.below(span),
            None => self.next_u64(), // full u64 range
        }
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed with the given mean (for Poisson arrivals).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Shuffle a slice in place (Fisher-Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn exp_has_requested_mean() {
        let mut r = Rng::new(11);
        let mean = 40.0;
        let n = 20_000;
        let total: f64 = (0..n).map(|_| r.exp(mean)).sum();
        let got = total / n as f64;
        assert!((got - mean).abs() / mean < 0.05, "mean {got}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn forked_streams_diverge() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
