//! The paper's evaluation job (§4.1.1): the "citizen journalism" live
//! video pipeline
//!
//! ```text
//! Partitioner -(all-to-all)-> Decoder -> Merger -> Overlay -> Encoder
//!             -(all-to-all)-> RTP Server
//! ```
//!
//! with m parallel instances of each type on n workers, 4 streams merged
//! per group, and one latency constraint over every runtime sequence
//! `(e1, vD, e2, vM, e3, vO, e4, vE, e5)` (Eq. 4).

use crate::graph::constraint::JobConstraint;
use crate::graph::ids::JobVertexId;
use crate::graph::job::{DistributionPattern, JobGraph};
use crate::graph::runtime::RuntimeGraph;
use crate::graph::sequence::JobSequence;
use crate::sim::cluster::SourceSpec;
use crate::sim::task::{KeyMap, OutBytes, Route, Semantics, TaskSpec};
use crate::util::time::Duration;
use anyhow::Result;

/// Workload parameters.  Defaults reproduce §4.2 scaled to the
/// simulation substrate (see DESIGN.md §3 for the calibration argument):
/// the paper's frame geometry (320x240, merged 2x2) with a frame rate
/// low enough that per-node link utilisation matches the testbed's
/// regime.  Task service times are calibrated from live XLA-kernel
/// timings of the L1/L2 artifacts (see EXPERIMENTS.md).
#[derive(Debug, Clone, Copy)]
pub struct VideoSpec {
    /// Degree of parallelism m per task type (§4.2: 800).
    pub parallelism: u32,
    /// Worker count n (§4.2: 200).
    pub workers: u32,
    /// Incoming video streams (§4.2: 6400).
    pub streams: u32,
    /// Streams merged per group (§4.2: 4).
    pub group_size: u32,
    /// Frames per second per stream.
    pub fps: f64,
    /// Compressed frame packet (bytes) on Partitioner->Decoder.
    pub packet_bytes: u64,
    /// Raw decoded frame (bytes) on Decoder->Merger.
    pub raw_frame_bytes: u64,
    /// Encoded merged frame (bytes) on Encoder->RTP.
    pub encoded_merged_bytes: u64,
    /// Latency constraint l (§4.2: 300 ms).
    pub constraint_ms: u64,
    /// Constraint/measurement window t (§4.2: 15 s).
    pub window_secs: u64,
    /// Per-frame service times (decode, merge, overlay, encode),
    /// calibrated from the live XLA artifacts.
    pub decode_service: Duration,
    pub merge_service: Duration,
    pub overlay_service: Duration,
    pub encode_service: Duration,
}

impl Default for VideoSpec {
    fn default() -> Self {
        VideoSpec {
            parallelism: 800,
            workers: 200,
            streams: 6400,
            group_size: 4,
            fps: 4.0,
            packet_bytes: 4 * 1024,
            raw_frame_bytes: 320 * 240 * 4,
            // Small re-encoded merged packets: this is what makes the
            // Encoder->RTP channel the slowest-filling one ("the number
            // of streams had been reduced by four and thus it took even
            // longer to fill a 32 KB buffer", §4.3.1).
            encoded_merged_bytes: 1024,
            constraint_ms: 300,
            window_secs: 15,
            decode_service: Duration::from_micros(4_000),
            merge_service: Duration::from_micros(800),
            overlay_service: Duration::from_micros(1_500),
            encode_service: Duration::from_micros(6_000),
        }
    }
}

impl VideoSpec {
    /// A laptop-scale configuration for tests and the quickstart.
    pub fn small() -> VideoSpec {
        VideoSpec {
            parallelism: 8,
            workers: 4,
            streams: 64,
            ..VideoSpec::default()
        }
    }
}

/// Everything needed to simulate or launch the job.
pub struct VideoJob {
    pub spec: VideoSpec,
    pub job: JobGraph,
    pub rg: RuntimeGraph,
    pub constraints: Vec<JobConstraint>,
    pub task_specs: Vec<TaskSpec>,
    pub sources: Vec<SourceSpec>,
    pub constrained_sequence: JobSequence,
    pub vertices: VideoVertices,
}

/// Job-vertex handles.
#[derive(Debug, Clone, Copy)]
pub struct VideoVertices {
    pub partitioner: JobVertexId,
    pub decoder: JobVertexId,
    pub merger: JobVertexId,
    pub overlay: JobVertexId,
    pub encoder: JobVertexId,
    pub rtp: JobVertexId,
}

/// Build the evaluation job.
pub fn video_job(spec: VideoSpec) -> Result<VideoJob> {
    assert_eq!(spec.streams % spec.group_size, 0, "streams divisible by group size");
    let groups = spec.streams / spec.group_size;
    assert_eq!(
        spec.streams % spec.parallelism,
        0,
        "streams spread evenly over partitioners/decoders"
    );
    let streams_per_decoder = spec.streams / spec.parallelism;
    assert_eq!(
        streams_per_decoder % spec.group_size,
        0,
        "whole groups per decoder so grouping happens at the Partitioner"
    );
    let groups_per_rtp = groups.div_ceil(spec.parallelism).max(1);

    let m = spec.parallelism;
    let mut job = JobGraph::new();
    let partitioner = job.add_vertex("Partitioner", m);
    let decoder = job.add_vertex("Decoder", m);
    let merger = job.add_vertex("Merger", m);
    let overlay = job.add_vertex("Overlay", m);
    let encoder = job.add_vertex("Encoder", m);
    let rtp = job.add_vertex("RTPServer", m);
    job.connect(partitioner, decoder, DistributionPattern::AllToAll);
    job.connect(decoder, merger, DistributionPattern::Pointwise);
    job.connect(merger, overlay, DistributionPattern::Pointwise);
    job.connect(overlay, encoder, DistributionPattern::Pointwise);
    job.connect(encoder, rtp, DistributionPattern::AllToAll);

    // Static CPU profiling estimates (fraction of one core) — refined at
    // runtime by TaskCpu measurements.
    let frames_per_task = streams_per_decoder as f64 * spec.fps;
    let util = |svc: Duration, per_sec: f64| (svc.as_secs_f64() * per_sec).min(1.0);
    job.vertex_mut(decoder).cpu_utilization = util(spec.decode_service, frames_per_task);
    job.vertex_mut(merger).cpu_utilization =
        util(spec.merge_service, frames_per_task);
    job.vertex_mut(overlay).cpu_utilization =
        util(spec.overlay_service, frames_per_task / spec.group_size as f64);
    job.vertex_mut(encoder).cpu_utilization =
        util(spec.encode_service, frames_per_task / spec.group_size as f64);
    job.validate()?;

    let rg = RuntimeGraph::expand(&job, spec.workers)?;

    // Eq. 4: (e1, vD, e2, vM, e3, vO, e4, vE, e5).
    let seq = JobSequence::along_path(
        &job,
        &[decoder, merger, overlay, encoder],
        Some(partitioner),
        Some(rtp),
    )?;
    let constraints = vec![JobConstraint::new(
        seq.clone(),
        Duration::from_millis(spec.constraint_ms),
        Duration::from_secs(spec.window_secs),
    )];

    // Task semantics per job vertex, in vertex order.
    let raw = spec.raw_frame_bytes;
    let merged = 4 * spec.raw_frame_bytes;
    let task_specs = vec![
        // Partitioner: forwards packets to the group's responsible
        // decoder ("assigns them to a group of streams and forwards the
        // video stream data to the Decoder task responsible for streams
        // of the assigned group").
        TaskSpec {
            semantics: Semantics::Transform,
            service: Duration::from_micros(30),
            out_bytes: OutBytes::Scale(1.0),
            key_map: KeyMap::Identity,
            route: Route::ByKey { divisor: streams_per_decoder },
            downstream_delay: Duration::ZERO,
        },
        // Decoder: packet -> raw frame.
        TaskSpec {
            semantics: Semantics::Transform,
            service: spec.decode_service,
            out_bytes: OutBytes::Const(raw),
            key_map: KeyMap::Identity,
            route: Route::Pointwise,
            downstream_delay: Duration::ZERO,
        },
        // Merger: group join of `group_size` streams -> merged frame;
        // output items are keyed by group id.
        TaskSpec {
            semantics: Semantics::Merge { arity: spec.group_size },
            service: spec.merge_service,
            out_bytes: OutBytes::Const(merged),
            key_map: KeyMap::DivideBy(spec.group_size),
            route: Route::Pointwise,
            downstream_delay: Duration::ZERO,
        },
        // Overlay: merged frame + marquee.
        TaskSpec {
            semantics: Semantics::Transform,
            service: spec.overlay_service,
            out_bytes: OutBytes::Const(merged),
            key_map: KeyMap::Identity,
            route: Route::Pointwise,
            downstream_delay: Duration::ZERO,
        },
        // Encoder: merged frame -> compressed stream packet.
        TaskSpec {
            semantics: Semantics::Transform,
            service: spec.encode_service,
            out_bytes: OutBytes::Const(spec.encoded_merged_bytes),
            key_map: KeyMap::Identity,
            route: Route::ByKey { divisor: groups_per_rtp },
            downstream_delay: Duration::ZERO,
        },
        // RTP server: sink.
        TaskSpec::sink(),
    ];

    // One external source per stream, phase-spread within a frame period.
    let interval = Duration::from_secs_f64(1.0 / spec.fps);
    let sources = (0..spec.streams)
        .map(|s| SourceSpec {
            key: s,
            target: partitioner,
            target_subtask: s % m,
            interval,
            bytes: spec.packet_bytes,
            offset: Duration::from_micros(
                (interval.as_micros() as u128 * s as u128 / spec.streams as u128) as u64,
            ),
            throttle: None,
            batch: 1,
        })
        .collect();

    Ok(VideoJob {
        spec,
        job,
        rg,
        constraints,
        task_specs,
        sources,
        constrained_sequence: seq,
        vertices: VideoVertices { partitioner, decoder, merger, overlay, encoder, rtp },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_matches_paper_scale() {
        let j = video_job(VideoSpec::default()).unwrap();
        assert_eq!(j.rg.vertices.len(), 6 * 800);
        assert_eq!(j.rg.channels.len(), 2 * 800 * 800 + 3 * 800);
        // 512e6 constrained runtime sequences (§3.4).
        assert_eq!(
            j.constraints[0].sequence.count_runtime(&j.job, &j.rg),
            512_000_000u128
        );
        assert_eq!(j.sources.len(), 6400);
    }

    #[test]
    fn small_spec_builds() {
        let j = video_job(VideoSpec::small()).unwrap();
        assert_eq!(j.rg.vertices.len(), 48);
        assert_eq!(j.task_specs.len(), 6);
        // 64 streams / 8 decoders = 8 streams per decoder = 2 groups.
        assert_eq!(j.sources.len(), 64);
    }

    #[test]
    fn grouping_stays_on_one_decoder() {
        let spec = VideoSpec::small();
        let streams_per_decoder = spec.streams / spec.parallelism;
        // All 4 streams of a group map to the same decoder index.
        for g in 0..(spec.streams / spec.group_size) {
            let members: Vec<u32> =
                (0..spec.group_size).map(|i| g * spec.group_size + i).collect();
            let decoders: std::collections::HashSet<u32> = members
                .iter()
                .map(|s| (s / streams_per_decoder) % spec.parallelism)
                .collect();
            assert_eq!(decoders.len(), 1, "group {g} split across decoders");
        }
    }

    #[test]
    fn cpu_estimates_allow_chaining() {
        // The paper chained Decoder..Encoder because their CPU sum fits
        // one core; our defaults must reproduce that precondition.
        let j = video_job(VideoSpec::default()).unwrap();
        let stages = [
            j.vertices.decoder,
            j.vertices.merger,
            j.vertices.overlay,
            j.vertices.encoder,
        ];
        let sum: f64 = stages
            .iter()
            .map(|&v| j.job.vertex(v).cpu_utilization)
            .sum();
        assert!(sum < 0.9, "cpu sum {sum} must stay below the chain budget");
    }
}
