//! Load-surge variant of the video pipeline (elastic-scaling scenario):
//!
//! ```text
//! Ingest -(all-to-all)-> Transcoder[elastic] -(all-to-all)-> RTPSink
//! ```
//!
//! A base set of streams starts at t=0 and is comfortably handled once
//! adaptive buffer sizing converges; at `surge_at` a second wave of
//! streams arrives and pushes the Transcoder group past CPU saturation.
//! Neither buffer sizing (the latency is input-queue wait, not buffer
//! residency) nor chaining (the constrained sequence holds a single
//! task) can fix that — only adding Transcoder instances can, which is
//! exactly the degree of freedom the scaling countermeasure adds.
//!
//! Both incident edges are all-to-all with key-hash routing, so the
//! channel fan-out re-partitions automatically as instances come and go.

use crate::graph::constraint::JobConstraint;
use crate::graph::ids::JobVertexId;
use crate::graph::job::{DistributionPattern, JobGraph};
use crate::graph::runtime::RuntimeGraph;
use crate::graph::sequence::JobSequence;
use crate::sim::cluster::SourceSpec;
use crate::sim::task::{KeyMap, OutBytes, Route, Semantics, TaskSpec};
use crate::util::time::Duration;
use anyhow::Result;

/// Workload parameters.  Defaults are sized so that the base load keeps
/// the two initial Transcoders at ~60% utilisation and the surge pushes
/// demand to ~120% — a clear overload that queues without bound until
/// the group is scaled.
#[derive(Debug, Clone, Copy)]
pub struct SurgeSpec {
    pub workers: u32,
    pub ingest_parallelism: u32,
    /// Initial Transcoder parallelism (the elastic group).
    pub transcoder_parallelism: u32,
    pub sink_parallelism: u32,
    /// Streams active from t=0.
    pub base_streams: u32,
    /// Additional streams arriving at `surge_at`.
    pub surge_streams: u32,
    pub surge_at: Duration,
    /// Frames per second per stream.
    pub fps: f64,
    /// Compressed frame packet bytes on Ingest->Transcoder.
    pub packet_bytes: u64,
    /// Transcoded packet bytes on Transcoder->RTPSink.
    pub transcoded_bytes: u64,
    /// Per-frame Transcoder service time.
    pub transcode_service: Duration,
    pub constraint_ms: u64,
    pub window_secs: u64,
    /// Scaling bounds handed to the manager configuration.
    pub max_parallelism: u32,
    pub scale_step: u32,
}

impl Default for SurgeSpec {
    fn default() -> Self {
        SurgeSpec {
            workers: 2,
            ingest_parallelism: 2,
            transcoder_parallelism: 2,
            sink_parallelism: 2,
            base_streams: 4,
            surge_streams: 4,
            surge_at: Duration::from_secs(60),
            fps: 50.0,
            packet_bytes: 2 * 1024,
            transcoded_bytes: 1024,
            transcode_service: Duration::from_micros(6_000),
            constraint_ms: 300,
            window_secs: 15,
            max_parallelism: 6,
            scale_step: 2,
        }
    }
}

impl SurgeSpec {
    /// Total arrival rate once the surge is active (items/s).
    pub fn peak_rate(&self) -> f64 {
        (self.base_streams + self.surge_streams) as f64 * self.fps
    }

    /// Transcoder CPU demand at the given rate, in cores.
    pub fn transcoder_demand(&self, rate: f64) -> f64 {
        rate * self.transcode_service.as_secs_f64()
    }
}

/// Job-vertex handles.
#[derive(Debug, Clone, Copy)]
pub struct SurgeVertices {
    pub ingest: JobVertexId,
    pub transcoder: JobVertexId,
    pub sink: JobVertexId,
}

/// Everything needed to simulate the load-surge job.
pub struct SurgeJob {
    pub spec: SurgeSpec,
    pub job: JobGraph,
    pub rg: RuntimeGraph,
    pub constraints: Vec<JobConstraint>,
    pub task_specs: Vec<TaskSpec>,
    pub sources: Vec<SourceSpec>,
    pub constrained_sequence: JobSequence,
    pub vertices: SurgeVertices,
}

/// Build the load-surge job.
pub fn surge_job(spec: SurgeSpec) -> Result<SurgeJob> {
    let mut job = JobGraph::new();
    let ingest = job.add_vertex("Ingest", spec.ingest_parallelism);
    let transcoder = job.add_vertex("Transcoder", spec.transcoder_parallelism);
    let sink = job.add_vertex("RTPSink", spec.sink_parallelism);
    job.connect(ingest, transcoder, DistributionPattern::AllToAll);
    job.connect(transcoder, sink, DistributionPattern::AllToAll);
    job.vertex_mut(transcoder).elastic = true;
    // Static profiling estimate at base load (refined at runtime by
    // TaskCpu measurements).
    let base_rate = spec.base_streams as f64 * spec.fps;
    job.vertex_mut(transcoder).cpu_utilization = (spec.transcoder_demand(base_rate)
        / spec.transcoder_parallelism as f64)
        .min(1.0);
    job.validate()?;
    let rg = RuntimeGraph::expand(&job, spec.workers)?;

    // Constraint over (e1, vTranscoder, e2).
    let seq = JobSequence::along_path(&job, &[transcoder], Some(ingest), Some(sink))?;
    let constraints = vec![JobConstraint::new(
        seq.clone(),
        Duration::from_millis(spec.constraint_ms),
        Duration::from_secs(spec.window_secs),
    )];

    let task_specs = vec![
        // Ingest: forwards stream packets, key-hashed over however many
        // Transcoder instances currently exist.
        TaskSpec {
            semantics: Semantics::Transform,
            service: Duration::from_micros(30),
            out_bytes: OutBytes::Scale(1.0),
            key_map: KeyMap::Identity,
            route: Route::ByKey { divisor: 1 },
            downstream_delay: Duration::ZERO,
        },
        // Transcoder: the CPU-heavy elastic stage.
        TaskSpec {
            semantics: Semantics::Transform,
            service: spec.transcode_service,
            out_bytes: OutBytes::Const(spec.transcoded_bytes),
            key_map: KeyMap::Identity,
            route: Route::ByKey { divisor: 1 },
            downstream_delay: Duration::ZERO,
        },
        TaskSpec::sink(),
    ];

    let interval = Duration::from_secs_f64(1.0 / spec.fps);
    let total = spec.base_streams + spec.surge_streams;
    let sources = (0..total)
        .map(|s| {
            let phase = Duration::from_micros(
                (interval.as_micros() as u128 * s as u128 / total.max(1) as u128) as u64,
            );
            let offset = if s < spec.base_streams {
                phase
            } else {
                spec.surge_at + phase
            };
            SourceSpec {
                key: s,
                target: ingest,
                target_subtask: s % spec.ingest_parallelism,
                interval,
                bytes: spec.packet_bytes,
                offset,
                throttle: None,
                batch: 1,
            }
        })
        .collect();

    Ok(SurgeJob {
        spec,
        job,
        rg,
        constraints,
        task_specs,
        sources,
        constrained_sequence: seq,
        vertices: SurgeVertices { ingest, transcoder, sink },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_with_defaults() {
        let sj = surge_job(SurgeSpec::default()).unwrap();
        assert_eq!(sj.job.vertices.len(), 3);
        assert_eq!(sj.rg.vertices.len(), 6);
        assert_eq!(sj.rg.channels.len(), 2 * 2 + 2 * 2);
        assert_eq!(sj.sources.len(), 8);
        assert!(sj.job.vertex(sj.vertices.transcoder).elastic);
        sj.constrained_sequence.validate(&sj.job).unwrap();
    }

    #[test]
    fn surge_overloads_initial_parallelism_but_not_the_maximum() {
        let spec = SurgeSpec::default();
        let base_rate = spec.base_streams as f64 * spec.fps;
        let base_demand = spec.transcoder_demand(base_rate);
        let peak_demand = spec.transcoder_demand(spec.peak_rate());
        assert!(
            base_demand < 0.9 * spec.transcoder_parallelism as f64,
            "base load must be comfortable: {base_demand}"
        );
        assert!(
            peak_demand > 1.1 * spec.transcoder_parallelism as f64,
            "surge must clearly overload the initial group: {peak_demand}"
        );
        assert!(
            peak_demand < 0.9 * spec.max_parallelism as f64,
            "the scaling bound must leave recovery headroom: {peak_demand}"
        );
    }

    #[test]
    fn surge_sources_start_late() {
        let spec = SurgeSpec::default();
        let sj = surge_job(spec).unwrap();
        for (i, s) in sj.sources.iter().enumerate() {
            if (i as u32) < spec.base_streams {
                assert!(s.offset < spec.surge_at);
            } else {
                assert!(s.offset >= spec.surge_at);
            }
        }
    }
}
