//! The paper's second motivating scenario (§1): energy informatics.
//! Smart meters report consumption readings; the utility's analytics
//! pipeline must act on fresh data ("especially in scenarios that
//! involve autonomous control actions, the freshness of the data that is
//! being acted upon is of paramount importance").
//!
//! ```text
//! Collector -(all-to-all, by feeder)-> Validator -> Aggregator(window)
//!           -> AlertEngine -(all-to-all)-> ControlRoom
//! ```

use crate::graph::constraint::JobConstraint;
use crate::graph::job::{DistributionPattern, JobGraph};
use crate::graph::runtime::RuntimeGraph;
use crate::graph::sequence::JobSequence;
use crate::sim::cluster::SourceSpec;
use crate::sim::task::{KeyMap, OutBytes, Route, Semantics, TaskSpec};
use crate::util::time::Duration;
use anyhow::Result;

/// Workload parameters for the smart-meter job.
#[derive(Debug, Clone, Copy)]
pub struct MeterSpec {
    pub parallelism: u32,
    pub workers: u32,
    /// Number of smart meters.
    pub meters: u32,
    /// Meters per grid feeder (aggregation key).
    pub meters_per_feeder: u32,
    /// Reporting interval per meter.
    pub report_interval: Duration,
    /// Reading payload bytes.
    pub reading_bytes: u64,
    /// Aggregation window of the per-feeder aggregator.
    pub window: Duration,
    /// Latency constraint for the control path.
    pub constraint_ms: u64,
    pub window_secs: u64,
}

impl Default for MeterSpec {
    fn default() -> Self {
        MeterSpec {
            parallelism: 16,
            workers: 8,
            meters: 4096,
            meters_per_feeder: 64,
            report_interval: Duration::from_millis(500),
            reading_bytes: 96,
            window: Duration::from_millis(1000),
            constraint_ms: 200,
            // The constraint window t must exceed the slowest channel's
            // initial buffer fill time, otherwise the manager never sees
            // a fresh full-sequence estimate ("there often was not
            // enough measurement data for the QoS Managers to act upon",
            // §4.3.2): alert channels fill 32 KB in ~64 s initially.
            window_secs: 120,
        }
    }
}

/// Build the smart-meter analytics job.
#[allow(clippy::type_complexity)]
pub fn smart_meter_job(
    spec: MeterSpec,
) -> Result<(
    JobGraph,
    RuntimeGraph,
    Vec<JobConstraint>,
    Vec<TaskSpec>,
    Vec<SourceSpec>,
    JobSequence,
)> {
    assert_eq!(spec.meters % spec.meters_per_feeder, 0);
    let feeders = spec.meters / spec.meters_per_feeder;
    let m = spec.parallelism;
    let feeders_per_validator = feeders.div_ceil(m).max(1);

    let mut job = JobGraph::new();
    let collector = job.add_vertex("Collector", m);
    let validator = job.add_vertex("Validator", m);
    let aggregator = job.add_vertex("Aggregator", m);
    let alerter = job.add_vertex("AlertEngine", m);
    let control = job.add_vertex("ControlRoom", m);
    job.connect(collector, validator, DistributionPattern::AllToAll);
    job.connect(validator, aggregator, DistributionPattern::Pointwise);
    job.connect(aggregator, alerter, DistributionPattern::Pointwise);
    job.connect(alerter, control, DistributionPattern::AllToAll);
    for jv in [validator, aggregator, alerter] {
        job.vertex_mut(jv).cpu_utilization = 0.05;
    }
    job.validate()?;
    let rg = RuntimeGraph::expand(&job, spec.workers)?;

    let seq = JobSequence::along_path(
        &job,
        &[validator, aggregator, alerter],
        Some(collector),
        Some(control),
    )?;
    let constraints = vec![JobConstraint::new(
        seq.clone(),
        Duration::from_millis(spec.constraint_ms),
        Duration::from_secs(spec.window_secs),
    )];

    let task_specs = vec![
        // Collector: receives readings, keys by meter id; routes whole
        // feeders to the responsible validator.
        TaskSpec {
            semantics: Semantics::Transform,
            service: Duration::from_micros(10),
            out_bytes: OutBytes::Scale(1.0),
            key_map: KeyMap::Identity,
            route: Route::ByKey { divisor: spec.meters_per_feeder * feeders_per_validator },
            downstream_delay: Duration::ZERO,
        },
        // Validator: sanity checks each reading.
        TaskSpec {
            semantics: Semantics::Transform,
            service: Duration::from_micros(50),
            out_bytes: OutBytes::Scale(1.2),
            key_map: KeyMap::Identity,
            route: Route::Pointwise,
            downstream_delay: Duration::ZERO,
        },
        // Aggregator: per-feeder window aggregation.
        TaskSpec {
            semantics: Semantics::WindowAgg { window: spec.window },
            service: Duration::from_micros(20),
            out_bytes: OutBytes::Const(256),
            key_map: KeyMap::DivideBy(spec.meters_per_feeder),
            route: Route::Pointwise,
            downstream_delay: Duration::ZERO,
        },
        // Alert engine: evaluates control rules on each aggregate.
        TaskSpec {
            semantics: Semantics::Transform,
            service: Duration::from_micros(100),
            out_bytes: OutBytes::Const(128),
            key_map: KeyMap::Identity,
            route: Route::ByKey { divisor: feeders_per_validator },
            downstream_delay: Duration::ZERO,
        },
        TaskSpec::sink(),
    ];

    let sources = (0..spec.meters)
        .map(|meter| SourceSpec {
            key: meter,
            target: collector,
            target_subtask: meter % m,
            interval: spec.report_interval,
            bytes: spec.reading_bytes,
            offset: Duration::from_micros(
                (spec.report_interval.as_micros() as u128 * meter as u128 / spec.meters as u128)
                    as u64,
            ),
            throttle: None,
            batch: 1,
        })
        .collect();

    Ok((job, rg, constraints, task_specs, sources, seq))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_with_defaults() {
        let (job, rg, constraints, specs, sources, seq) =
            smart_meter_job(MeterSpec::default()).unwrap();
        assert_eq!(job.vertices.len(), 5);
        assert_eq!(rg.vertices.len(), 5 * 16);
        assert_eq!(constraints.len(), 1);
        assert_eq!(specs.len(), 5);
        assert_eq!(sources.len(), 4096);
        seq.validate(&job).unwrap();
    }

    #[test]
    fn feeders_map_to_single_validator() {
        let spec = MeterSpec::default();
        let feeders = spec.meters / spec.meters_per_feeder;
        let fpv = feeders.div_ceil(spec.parallelism).max(1);
        for f in 0..feeders {
            let members: Vec<u32> = (0..spec.meters_per_feeder)
                .map(|i| f * spec.meters_per_feeder + i)
                .collect();
            let validators: std::collections::HashSet<u32> = members
                .iter()
                .map(|mtr| (mtr / (spec.meters_per_feeder * fpv)) % spec.parallelism)
                .collect();
            assert_eq!(validators.len(), 1, "feeder {f} split");
        }
    }
}
