//! Job builders: the paper's evaluation workloads expressed against the
//! public API (job graph + constraints + task semantics + sources).

pub mod failover;
pub mod meter;
pub mod microbench;
pub mod multi;
pub mod scale;
pub mod surge;
pub mod video;

pub use failover::{failover_job, FailoverJob, FailoverSpec};
pub use meter::{smart_meter_job, MeterSpec};
pub use microbench::{sender_receiver_job, MicrobenchSpec};
pub use multi::MultiSpec;
pub use scale::ScaleSpec;
pub use surge::{surge_job, SurgeJob, SurgeSpec};
pub use video::{video_job, VideoJob, VideoSpec};
