//! Worker-failure variant of the video pipeline (fault-tolerance
//! scenario):
//!
//! ```text
//! Ingest[pinned] -(all-to-all)-> Transcoder -(all-to-all)-> RTPSink
//! ```
//!
//! The Ingest stage carries the §3.6 `pin_unchainable` annotation: it is
//! a materialisation point, so every item it emits survives in a durable
//! buffer until the downstream segment has consumed it.  One worker is
//! placed so that it hosts exactly one Transcoder instance, and a
//! [`FailureSpec`] crashes it mid-run.
//!
//! * With recovery enabled, the master detects the silent worker,
//!   redeploys the dead instance onto a surviving worker, replays the
//!   items stashed at the Ingest materialisation points, and the
//!   restored parallelism works the replay backlog off — the constraint
//!   returns to satisfied.
//! * With recovery disabled, the dead instance is merely detached;
//!   key-hash routing funnels *all* streams through the surviving
//!   Transcoder, whose demand is sized above one task thread — the
//!   constraint stays violated, and with buffer sizing converged and no
//!   chainable pair on the single-task sequence the managers escalate to
//!   the failed-optimisation report (`Unresolvable`).
//!
//! Items travelling Transcoder→RTPSink at crash time have an *unpinned*
//! producer: they are accounted as lost explicitly, never replayed.

use crate::config::FailureSpec;
use crate::graph::constraint::JobConstraint;
use crate::graph::ids::{JobVertexId, WorkerId};
use crate::graph::job::{DistributionPattern, JobGraph};
use crate::graph::runtime::RuntimeGraph;
use crate::graph::sequence::JobSequence;
use crate::sim::cluster::SourceSpec;
use crate::sim::task::{KeyMap, OutBytes, Route, Semantics, TaskSpec};
use crate::util::time::Duration;
use anyhow::{bail, Result};

/// Workload parameters.  Defaults keep each of the two Transcoders at
/// ~60% of a task thread, so losing one (without recovery) leaves the
/// survivor at ~120% — an overload neither buffer sizing nor chaining
/// can fix, while redeployment restores the comfortable 60%.
#[derive(Debug, Clone, Copy)]
pub struct FailoverSpec {
    pub workers: u32,
    pub ingest_parallelism: u32,
    pub transcoder_parallelism: u32,
    pub sink_parallelism: u32,
    /// Streams active from t=0.
    pub streams: u32,
    /// Frames per second per stream.
    pub fps: f64,
    /// Compressed frame packet bytes on Ingest->Transcoder.
    pub packet_bytes: u64,
    /// Transcoded packet bytes on Transcoder->RTPSink.
    pub transcoded_bytes: u64,
    /// Per-frame Transcoder service time.
    pub transcode_service: Duration,
    pub constraint_ms: u64,
    pub window_secs: u64,
    /// The worker the failure injector crashes; hosts exactly one
    /// Transcoder instance and nothing else.
    pub fail_worker: u32,
    /// Crash time.
    pub fail_at: Duration,
}

impl Default for FailoverSpec {
    fn default() -> Self {
        FailoverSpec {
            workers: 3,
            ingest_parallelism: 2,
            transcoder_parallelism: 2,
            sink_parallelism: 2,
            streams: 6,
            fps: 50.0,
            packet_bytes: 2 * 1024,
            transcoded_bytes: 1024,
            transcode_service: Duration::from_micros(4_000),
            constraint_ms: 300,
            window_secs: 15,
            fail_worker: 2,
            fail_at: Duration::from_secs(90),
        }
    }
}

impl FailoverSpec {
    /// Total arrival rate (items/s).
    pub fn rate(&self) -> f64 {
        self.streams as f64 * self.fps
    }

    /// Transcoder CPU demand in task threads.
    pub fn transcoder_demand(&self) -> f64 {
        self.rate() * self.transcode_service.as_secs_f64()
    }

    /// The injected failure.
    pub fn failure(&self) -> FailureSpec {
        FailureSpec { worker: WorkerId(self.fail_worker), at: self.fail_at }
    }
}

/// Job-vertex handles.
#[derive(Debug, Clone, Copy)]
pub struct FailoverVertices {
    pub ingest: JobVertexId,
    pub transcoder: JobVertexId,
    pub sink: JobVertexId,
}

/// Everything needed to simulate the failover job.
pub struct FailoverJob {
    pub spec: FailoverSpec,
    pub job: JobGraph,
    pub rg: RuntimeGraph,
    pub constraints: Vec<JobConstraint>,
    pub task_specs: Vec<TaskSpec>,
    pub sources: Vec<SourceSpec>,
    pub constrained_sequence: JobSequence,
    pub vertices: FailoverVertices,
}

/// Build the failover job.
pub fn failover_job(spec: FailoverSpec) -> Result<FailoverJob> {
    if spec.workers < 2 {
        bail!("failover scenario needs at least 2 workers (one must survive)");
    }
    if spec.fail_worker >= spec.workers {
        bail!("fail_worker {} out of range (workers {})", spec.fail_worker, spec.workers);
    }
    if spec.transcoder_parallelism < 2 {
        bail!("need at least 2 Transcoders (one must survive the crash)");
    }
    let mut job = JobGraph::new();
    let ingest = job.add_vertex("Ingest", spec.ingest_parallelism);
    let transcoder = job.add_vertex("Transcoder", spec.transcoder_parallelism);
    let sink = job.add_vertex("RTPSink", spec.sink_parallelism);
    job.connect(ingest, transcoder, DistributionPattern::AllToAll);
    job.connect(transcoder, sink, DistributionPattern::AllToAll);
    // §3.6: Ingest is the materialisation point the recovery replays from.
    job.vertex_mut(ingest).pin_unchainable = true;
    job.vertex_mut(transcoder).cpu_utilization =
        (spec.transcoder_demand() / spec.transcoder_parallelism as f64).min(1.0);
    job.validate()?;

    // Placement: the doomed worker hosts exactly one Transcoder instance
    // (the last subtask); everything else spreads over the survivors.
    // This keeps external streams attached to live Ingest endpoints
    // across the crash, so the workload itself never changes.
    let doomed = spec.fail_worker;
    let others: Vec<u32> = (0..spec.workers).filter(|&w| w != doomed).collect();
    let last_transcoder = spec.transcoder_parallelism - 1;
    let rg = RuntimeGraph::expand_with(&job, spec.workers, &|jv, s| {
        if jv == transcoder && s == last_transcoder {
            WorkerId(doomed)
        } else {
            WorkerId(others[s as usize % others.len()])
        }
    })?;

    // Constraint over (e1, vTranscoder, e2).
    let seq = JobSequence::along_path(&job, &[transcoder], Some(ingest), Some(sink))?;
    let constraints = vec![JobConstraint::new(
        seq.clone(),
        Duration::from_millis(spec.constraint_ms),
        Duration::from_secs(spec.window_secs),
    )];

    let task_specs = vec![
        // Ingest: forwards stream packets, key-hashed over the live
        // Transcoder instances.
        TaskSpec {
            semantics: Semantics::Transform,
            service: Duration::from_micros(30),
            out_bytes: OutBytes::Scale(1.0),
            key_map: KeyMap::Identity,
            route: Route::ByKey { divisor: 1 },
            downstream_delay: Duration::ZERO,
        },
        // Transcoder: the CPU-heavy stage whose instance dies.
        TaskSpec {
            semantics: Semantics::Transform,
            service: spec.transcode_service,
            out_bytes: OutBytes::Const(spec.transcoded_bytes),
            key_map: KeyMap::Identity,
            route: Route::ByKey { divisor: 1 },
            downstream_delay: Duration::ZERO,
        },
        TaskSpec::sink(),
    ];

    let interval = Duration::from_secs_f64(1.0 / spec.fps);
    let sources = (0..spec.streams)
        .map(|s| {
            let phase = Duration::from_micros(
                (interval.as_micros() as u128 * s as u128 / spec.streams.max(1) as u128) as u64,
            );
            SourceSpec {
                key: s,
                target: ingest,
                target_subtask: s % spec.ingest_parallelism,
                interval,
                bytes: spec.packet_bytes,
                offset: phase,
                throttle: None,
                batch: 1,
            }
        })
        .collect();

    Ok(FailoverJob {
        spec,
        job,
        rg,
        constraints,
        task_specs,
        sources,
        constrained_sequence: seq,
        vertices: FailoverVertices { ingest, transcoder, sink },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_with_defaults() {
        let fj = failover_job(FailoverSpec::default()).unwrap();
        assert_eq!(fj.job.vertices.len(), 3);
        assert_eq!(fj.rg.vertices.len(), 6);
        assert_eq!(fj.sources.len(), 6);
        assert!(fj.job.vertex(fj.vertices.ingest).pin_unchainable);
        assert!(!fj.job.vertex(fj.vertices.transcoder).pin_unchainable);
        fj.constrained_sequence.validate(&fj.job).unwrap();
    }

    #[test]
    fn doomed_worker_hosts_exactly_one_transcoder() {
        let spec = FailoverSpec::default();
        let fj = failover_job(spec).unwrap();
        let doomed = WorkerId(spec.fail_worker);
        let hosted: Vec<_> = fj.rg.vertices_on_worker(doomed).collect();
        assert_eq!(hosted.len(), 1, "crash must take down exactly one instance");
        assert_eq!(hosted[0].job_vertex, fj.vertices.transcoder);
        // External streams stay attached to surviving Ingest endpoints.
        for s in &fj.sources {
            let v = fj.rg.members(s.target)[s.target_subtask as usize];
            assert_ne!(fj.rg.worker(v), doomed);
        }
    }

    #[test]
    fn losing_one_transcoder_overloads_the_survivor_but_base_load_is_comfortable() {
        let spec = FailoverSpec::default();
        let demand = spec.transcoder_demand();
        let per_instance = demand / spec.transcoder_parallelism as f64;
        assert!(per_instance < 0.9, "base load must be comfortable: {per_instance}");
        let survivor_load = demand / (spec.transcoder_parallelism - 1) as f64;
        assert!(
            survivor_load > 1.05,
            "unrecovered crash must overload the survivor: {survivor_load}"
        );
    }

    #[test]
    fn invalid_specs_are_rejected() {
        let mut s = FailoverSpec::default();
        s.workers = 1;
        assert!(failover_job(s).is_err());
        let mut s = FailoverSpec::default();
        s.fail_worker = 3;
        assert!(failover_job(s).is_err());
        let mut s = FailoverSpec::default();
        s.transcoder_parallelism = 1;
        assert!(failover_job(s).is_err());
    }
}
