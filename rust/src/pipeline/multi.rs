//! The multi-job workload (`nephele sim-multi`): staggered arrivals of
//! several latency-constrained video pipelines plus one
//! throughput-oriented Hadoop-Online-style batch job, all contending
//! for the same pool of workers.
//!
//! This is the workload dimension the paper's §2 design principles
//! argue for — many individually-trivial jobs whose *aggregate* needs a
//! massively-parallel framework — and it makes the QoS control loop
//! earn its keep under contention: every latency job must end within
//! its constraint tolerance while the throughput job's sink rate is
//! preserved, under every placement policy.
//!
//! One [`MultiSpec`] derives all submissions, so the scenario is sized
//! coherently: the slot ledger holds every job at peak concurrency with
//! headroom for elastic scaling, and group/stream counts satisfy the
//! divisibility rules of both pipeline builders.

use crate::baseline::hadoop::{hadoop_online_job, HadoopSpec};
use crate::pipeline::surge::{surge_job, SurgeSpec};
use crate::pipeline::video::{video_job, VideoSpec};
use crate::qos::manager::ManagerConfig;
use crate::sched::JobSpec;
use crate::util::time::Duration;
use anyhow::Result;

/// Parameters of the multi-job scenario.
#[derive(Debug, Clone, Copy)]
pub struct MultiSpec {
    /// Shared worker pool size.
    pub workers: u32,
    /// Task slots per worker (the scheduler's capacity unit).
    pub slots_per_worker: u32,
    /// Number of latency-constrained video pipelines.
    pub latency_jobs: u32,
    /// Parallelism per task type of each latency job.
    pub latency_parallelism: u32,
    /// External streams per latency job.
    pub latency_streams: u32,
    /// Streams merged per group (both job kinds).
    pub group_size: u32,
    /// Frames per second per stream.
    pub fps: f64,
    /// Latency constraint l per latency job (ms).
    pub constraint_ms: u64,
    pub window_secs: u64,
    /// Submission spacing between consecutive latency jobs (s).
    pub stagger_secs: u64,
    /// Source lifetime of each latency job (s after its submission).
    pub latency_job_secs: u64,
    /// Parallelism per task type of the throughput job.
    pub throughput_parallelism: u32,
    pub throughput_streams: u32,
    /// Source lifetime of the throughput job (submitted at t=0).
    pub throughput_secs: u64,
    /// Per-job QoS warm-up before the tail measurement starts (s).
    pub warm_secs: u64,
}

impl Default for MultiSpec {
    fn default() -> Self {
        MultiSpec {
            workers: 16,
            slots_per_worker: 8,
            latency_jobs: 4,
            latency_parallelism: 4,
            latency_streams: 32,
            group_size: 4,
            fps: 4.0,
            constraint_ms: 300,
            window_secs: 15,
            stagger_secs: 45,
            latency_job_secs: 300,
            throughput_parallelism: 4,
            throughput_streams: 16,
            throughput_secs: 495,
            warm_secs: 150,
        }
    }
}

impl MultiSpec {
    /// Reduced configuration for CI smoke runs and tests: fewer and
    /// smaller jobs on a smaller pool, same code path.
    pub fn quick() -> MultiSpec {
        MultiSpec {
            workers: 8,
            slots_per_worker: 8,
            latency_jobs: 3,
            latency_parallelism: 2,
            latency_streams: 16,
            stagger_secs: 30,
            latency_job_secs: 240,
            throughput_parallelism: 4,
            throughput_streams: 16,
            throughput_secs: 330,
            warm_secs: 150,
            ..MultiSpec::default()
        }
    }

    /// Minimal configuration for the (debug-build) test suite.
    pub fn tiny() -> MultiSpec {
        MultiSpec {
            workers: 4,
            slots_per_worker: 10,
            latency_jobs: 2,
            latency_parallelism: 2,
            latency_streams: 16,
            stagger_secs: 20,
            latency_job_secs: 180,
            throughput_parallelism: 2,
            throughput_streams: 8,
            throughput_secs: 230,
            warm_secs: 120,
            ..MultiSpec::default()
        }
    }

    /// Submission time of latency job `idx`.
    pub fn latency_submit_at(&self, idx: u32) -> Duration {
        Duration::from_secs(self.stagger_secs * idx as u64)
    }

    /// Steady-state sink rate of one latency job (merged frames/s).
    pub fn latency_expected_rate(&self) -> f64 {
        (self.latency_streams / self.group_size) as f64 * self.fps
    }

    /// Steady-state sink rate of the throughput job: merged frames per
    /// second divided by the frames the reduce-side window folds into
    /// one emission (see `experiments/scale.rs` for the derivation).
    pub fn throughput_expected_rate(&self) -> f64 {
        let merged = (self.throughput_streams / self.group_size) as f64 * self.fps;
        let frame_interval = 1.0 / self.fps;
        let window = HadoopSpec::default().reduce_window.as_secs_f64();
        let frames_per_emit = (window / frame_interval).ceil() + 1.0;
        merged / frames_per_emit
    }

    /// Total instances at peak concurrency (for capacity sizing): all
    /// jobs overlap in the worst case.
    pub fn peak_demand(&self) -> u32 {
        // Video pipeline: 6 task types; HOP expression: 5.
        self.latency_jobs * 6 * self.latency_parallelism + 5 * self.throughput_parallelism
    }

    /// Slot capacity of the pool.
    pub fn capacity(&self) -> u32 {
        self.workers * self.slots_per_worker
    }
}

/// Monitoring-only countermeasure arming (the HOP/best-effort posture:
/// constraints are observed, never acted on).
pub fn monitoring_only() -> ManagerConfig {
    ManagerConfig {
        enable_buffer_sizing: false,
        enable_chaining: false,
        enable_scaling: false,
        ..ManagerConfig::default()
    }
}

/// Build the spec for latency job `idx`: the §4.1.1 video pipeline
/// under the paper's constraint, sized per the scenario spec.  The
/// runtime expansion the builder performs is discarded — placement is
/// the scheduler's job at admission time.
pub fn latency_submission(spec: &MultiSpec, idx: u32) -> Result<JobSpec> {
    let vspec = VideoSpec {
        parallelism: spec.latency_parallelism,
        workers: spec.workers,
        streams: spec.latency_streams,
        group_size: spec.group_size,
        fps: spec.fps,
        constraint_ms: spec.constraint_ms,
        window_secs: spec.window_secs,
        ..VideoSpec::default()
    };
    let vj = video_job(vspec)?;
    // Engine-default manager: the cluster arms full QoS.
    Ok(
        JobSpec::new(format!("video-{idx}"), vj.job, vj.constraints, vj.task_specs, vj.sources)
            .run_for(Duration::from_secs(spec.latency_job_secs)),
    )
}

/// Build the throughput job: the §4.1.2 Hadoop-Online expression of the
/// video workload, running *unoptimised* (static 32 KB buffers, no
/// chaining — HOP has no QoS management) under a monitoring-only
/// constraint.  Its yardstick is sink rate, not latency; as a
/// best-effort job it is also the preemption victim class.
pub fn throughput_submission(spec: &MultiSpec) -> Result<JobSpec> {
    let hspec = HadoopSpec {
        parallelism: spec.throughput_parallelism,
        workers: spec.workers,
        streams: spec.throughput_streams,
        group_size: spec.group_size,
        fps: spec.fps,
        ..HadoopSpec::default()
    };
    let hj = hadoop_online_job(hspec)?;
    Ok(
        JobSpec::new("hadoop-batch", hj.job, hj.constraints, hj.task_specs, hj.sources)
            .run_for(Duration::from_secs(spec.throughput_secs))
            .with_manager(monitoring_only())
            .best_effort(),
    )
}

// ---------------------------------------------------------------------
// Phase workloads (admission / fairness / preemption scenario phases)
// ---------------------------------------------------------------------

/// A small deterministic 3-stage pipeline (the surge shape without its
/// surge wave): 2/2/2 parallelism = 6 slots, base load at ~60% of the
/// two Transcoders.  The workhorse of the lifecycle phases.
pub fn holder_submission(name: &str, run_for: Duration) -> Result<JobSpec> {
    let mut s = SurgeSpec::default();
    s.surge_streams = 0;
    let sj = surge_job(s)?;
    Ok(
        JobSpec::new(name, sj.job, sj.constraints, sj.task_specs, sj.sources)
            .run_for(run_for),
    )
}

/// A submission whose 6/6/6 = 18-slot demand exceeds the admission
/// phase's whole 16-slot cluster: must be rejected `exceeds-capacity`.
pub fn oversized_submission(name: &str) -> Result<JobSpec> {
    let mut s = SurgeSpec::default();
    s.surge_streams = 0;
    s.base_streams = 6;
    s.ingest_parallelism = 6;
    s.transcoder_parallelism = 6;
    s.sink_parallelism = 6;
    let sj = surge_job(s)?;
    Ok(JobSpec::new(name, sj.job, sj.constraints, sj.task_specs, sj.sources))
}

/// A fairness-phase contender: the holder pipeline with an explicit
/// fair-share weight, competing for elastic slots.
pub fn contender_submission(name: &str, weight: u32, run_for: Duration) -> Result<JobSpec> {
    Ok(holder_submission(name, run_for)?.with_weight(weight))
}

/// The preemption victim: a best-effort (priority 0) holder pipeline at
/// reduced rate, monitoring-only QoS.  After losing one of its two
/// Transcoders it still keeps up (4 × 25 fps × 6 ms = 0.6 cores).
pub fn victim_submission(run_for: Duration) -> Result<JobSpec> {
    let mut s = SurgeSpec::default();
    s.surge_streams = 0;
    s.fps = 25.0;
    let sj = surge_job(s)?;
    Ok(
        JobSpec::new("best-effort", sj.job, sj.constraints, sj.task_specs, sj.sources)
            .run_for(run_for)
            .with_manager(monitoring_only())
            .best_effort(),
    )
}

/// The migration phase's latency job: a minimal 1/1/1 pipeline at low
/// rate (2 × 25 fps) under the engine-default manager.  Spread
/// placement puts its Transcoder on the same worker as [`nic_noise_submission`]'s,
/// so its sink traffic queues behind the noise job's NIC backlog until
/// the governance loop migrates one of them off the hot link.
pub fn nic_victim_submission(run_for: Duration) -> Result<JobSpec> {
    let mut s = SurgeSpec::default();
    s.surge_streams = 0;
    s.base_streams = 2;
    s.ingest_parallelism = 1;
    s.transcoder_parallelism = 1;
    s.sink_parallelism = 1;
    s.fps = 25.0;
    let sj = surge_job(s)?;
    Ok(
        JobSpec::new("latency-victim", sj.job, sj.constraints, sj.task_specs, sj.sources)
            .run_for(run_for),
    )
}

/// The migration phase's NIC hog: same 1/1/1 shape, negligible CPU
/// (1 ms service), but 64 KiB transcoded packets — 50/s × 64 KiB =
/// 3.28 MB/s of Transcoder egress against the phase's throttled 2 MB/s
/// links, so the shared worker's NIC backlog grows without bound.
/// Best-effort and monitoring-only: *its* manager never acts; only the
/// cluster-level governance loop can resolve the saturation.
pub fn nic_noise_submission(run_for: Duration) -> Result<JobSpec> {
    let mut s = SurgeSpec::default();
    s.surge_streams = 0;
    s.base_streams = 2;
    s.ingest_parallelism = 1;
    s.transcoder_parallelism = 1;
    s.sink_parallelism = 1;
    s.fps = 25.0;
    s.packet_bytes = 512;
    s.transcoded_bytes = 64 * 1024;
    s.transcode_service = Duration::from_micros(1_000);
    let sj = surge_job(s)?;
    Ok(
        JobSpec::new("nic-hog", sj.job, sj.constraints, sj.task_specs, sj.sources)
            .run_for(run_for)
            .with_manager(monitoring_only())
            .best_effort(),
    )
}

/// The preempting latency-critical job: priority 2, a single Transcoder
/// that full base load (4 × 50 fps × 6 ms = 1.2 cores) overloads — only
/// one more Transcoder instance meets the constraint, and on a full
/// pool that slot must come out of the best-effort victim.
pub fn highpri_submission(run_for: Duration) -> Result<JobSpec> {
    let mut s = SurgeSpec::default();
    s.surge_streams = 0;
    s.transcoder_parallelism = 1;
    s.sink_parallelism = 1;
    let sj = surge_job(s)?;
    Ok(
        JobSpec::new("latency-critical", sj.job, sj.constraints, sj.task_specs, sj.sources)
            .run_for(run_for)
            .with_priority(2),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_fit_their_slot_capacity() {
        for spec in [MultiSpec::default(), MultiSpec::quick(), MultiSpec::tiny()] {
            assert!(
                spec.peak_demand() <= spec.capacity(),
                "peak demand {} exceeds capacity {}",
                spec.peak_demand(),
                spec.capacity()
            );
            // The throughput job outlives the last latency job, so the
            // contention window covers every latency job's whole life.
            let last_end =
                spec.stagger_secs * (spec.latency_jobs as u64 - 1) + spec.latency_job_secs;
            assert!(spec.throughput_secs >= last_end);
            // Warm-up leaves a real measurement tail.
            assert!(spec.warm_secs < spec.latency_job_secs);
        }
    }

    #[test]
    fn submissions_build_and_are_consistent() {
        use crate::sched::QosClass;
        let spec = MultiSpec::tiny();
        for i in 0..spec.latency_jobs {
            let sub = latency_submission(&spec, i).unwrap();
            assert_eq!(sub.job.vertices.len(), 6);
            assert_eq!(sub.task_specs.len(), 6);
            assert_eq!(sub.sources.len(), spec.latency_streams as usize);
            assert_eq!(sub.constraints.len(), 1);
            assert!(sub.manager.is_none());
            assert_eq!(sub.class, QosClass::LatencyConstrained);
            assert_eq!((sub.priority, sub.weight), (1, 1));
            assert_eq!(sub.job.slot_demand(), 6 * spec.latency_parallelism);
        }
        let t = throughput_submission(&spec).unwrap();
        assert_eq!(t.job.vertices.len(), 5);
        assert_eq!(t.class, QosClass::BestEffort);
        assert_eq!(t.priority, 0);
        let mgr = t.manager.unwrap();
        assert!(!mgr.enable_buffer_sizing && !mgr.enable_chaining && !mgr.enable_scaling);
    }

    #[test]
    fn phase_workloads_carry_their_governance_intent() {
        use crate::sched::QosClass;
        let h = holder_submission("h", Duration::from_secs(60)).unwrap();
        assert_eq!(h.job.slot_demand(), 6);
        assert_eq!(h.run_for, Some(Duration::from_secs(60)));
        let o = oversized_submission("o").unwrap();
        assert_eq!(o.job.slot_demand(), 18);
        let c = contender_submission("c", 2, Duration::from_secs(60)).unwrap();
        assert_eq!((c.weight, c.job.slot_demand()), (2, 6));
        let v = victim_submission(Duration::from_secs(60)).unwrap();
        assert_eq!(v.class, QosClass::BestEffort);
        assert_eq!(v.job.slot_demand(), 6);
        // The victim keeps up on one Transcoder after preemption...
        assert!(v.job.vertex_by_name("Transcoder").unwrap().cpu_utilization * 2.0 <= 0.9);
        let nv = nic_victim_submission(Duration::from_secs(60)).unwrap();
        assert_eq!(nv.job.slot_demand(), 3);
        assert_eq!(nv.class, QosClass::LatencyConstrained);
        assert!(nv.manager.is_none(), "the victim runs the engine-default manager");
        let nh = nic_noise_submission(Duration::from_secs(60)).unwrap();
        assert_eq!(nh.job.slot_demand(), 3);
        assert_eq!(nh.class, QosClass::BestEffort);
        assert!(nh.manager.is_some(), "the hog is monitoring-only");
        // The hog's transcoder egress alone exceeds the migrate phase's
        // 2 MB/s link rate — the saturation is structural, not a burst.
        let rate = 2.0 * 25.0;
        assert!(rate * 64.0 * 1024.0 > 2.0e6);
        let p = highpri_submission(Duration::from_secs(60)).unwrap();
        assert_eq!((p.class, p.priority), (QosClass::LatencyConstrained, 2));
        assert_eq!(p.job.slot_demand(), 4);
        // ...while the high-priority job overloads its single one (the
        // profile is clamped at 1.0 core) and needs the preempted slot.
        assert_eq!(p.job.vertex_by_name("Transcoder").unwrap().cpu_utilization, 1.0);
    }

    #[test]
    fn expected_rates_match_the_scale_scenario_math() {
        let spec = MultiSpec::quick();
        // 16 streams / 4 per group * 4 fps = 16 merged frames/s.
        assert_eq!(spec.latency_expected_rate(), 16.0);
        // HOP window (100 ms) at 4 fps folds 2 frames per emission.
        assert_eq!(spec.throughput_expected_rate(), 8.0);
    }
}
