//! The multi-job workload (`nephele sim-multi`): staggered arrivals of
//! several latency-constrained video pipelines plus one
//! throughput-oriented Hadoop-Online-style batch job, all contending
//! for the same pool of workers.
//!
//! This is the workload dimension the paper's §2 design principles
//! argue for — many individually-trivial jobs whose *aggregate* needs a
//! massively-parallel framework — and it makes the QoS control loop
//! earn its keep under contention: every latency job must end within
//! its constraint tolerance while the throughput job's sink rate is
//! preserved, under every placement policy.
//!
//! One [`MultiSpec`] derives all submissions, so the scenario is sized
//! coherently: the slot ledger holds every job at peak concurrency with
//! headroom for elastic scaling, and group/stream counts satisfy the
//! divisibility rules of both pipeline builders.

use crate::baseline::hadoop::{hadoop_online_job, HadoopSpec};
use crate::pipeline::video::{video_job, VideoSpec};
use crate::qos::manager::ManagerConfig;
use crate::sched::JobSubmission;
use crate::util::time::Duration;
use anyhow::Result;

/// Parameters of the multi-job scenario.
#[derive(Debug, Clone, Copy)]
pub struct MultiSpec {
    /// Shared worker pool size.
    pub workers: u32,
    /// Task slots per worker (the scheduler's capacity unit).
    pub slots_per_worker: u32,
    /// Number of latency-constrained video pipelines.
    pub latency_jobs: u32,
    /// Parallelism per task type of each latency job.
    pub latency_parallelism: u32,
    /// External streams per latency job.
    pub latency_streams: u32,
    /// Streams merged per group (both job kinds).
    pub group_size: u32,
    /// Frames per second per stream.
    pub fps: f64,
    /// Latency constraint l per latency job (ms).
    pub constraint_ms: u64,
    pub window_secs: u64,
    /// Submission spacing between consecutive latency jobs (s).
    pub stagger_secs: u64,
    /// Source lifetime of each latency job (s after its submission).
    pub latency_job_secs: u64,
    /// Parallelism per task type of the throughput job.
    pub throughput_parallelism: u32,
    pub throughput_streams: u32,
    /// Source lifetime of the throughput job (submitted at t=0).
    pub throughput_secs: u64,
    /// Per-job QoS warm-up before the tail measurement starts (s).
    pub warm_secs: u64,
}

impl Default for MultiSpec {
    fn default() -> Self {
        MultiSpec {
            workers: 16,
            slots_per_worker: 8,
            latency_jobs: 4,
            latency_parallelism: 4,
            latency_streams: 32,
            group_size: 4,
            fps: 4.0,
            constraint_ms: 300,
            window_secs: 15,
            stagger_secs: 45,
            latency_job_secs: 300,
            throughput_parallelism: 4,
            throughput_streams: 16,
            throughput_secs: 495,
            warm_secs: 150,
        }
    }
}

impl MultiSpec {
    /// Reduced configuration for CI smoke runs and tests: fewer and
    /// smaller jobs on a smaller pool, same code path.
    pub fn quick() -> MultiSpec {
        MultiSpec {
            workers: 8,
            slots_per_worker: 8,
            latency_jobs: 3,
            latency_parallelism: 2,
            latency_streams: 16,
            stagger_secs: 30,
            latency_job_secs: 240,
            throughput_parallelism: 4,
            throughput_streams: 16,
            throughput_secs: 330,
            warm_secs: 150,
            ..MultiSpec::default()
        }
    }

    /// Minimal configuration for the (debug-build) test suite.
    pub fn tiny() -> MultiSpec {
        MultiSpec {
            workers: 4,
            slots_per_worker: 10,
            latency_jobs: 2,
            latency_parallelism: 2,
            latency_streams: 16,
            stagger_secs: 20,
            latency_job_secs: 180,
            throughput_parallelism: 2,
            throughput_streams: 8,
            throughput_secs: 230,
            warm_secs: 120,
            ..MultiSpec::default()
        }
    }

    /// Submission time of latency job `idx`.
    pub fn latency_submit_at(&self, idx: u32) -> Duration {
        Duration::from_secs(self.stagger_secs * idx as u64)
    }

    /// Steady-state sink rate of one latency job (merged frames/s).
    pub fn latency_expected_rate(&self) -> f64 {
        (self.latency_streams / self.group_size) as f64 * self.fps
    }

    /// Steady-state sink rate of the throughput job: merged frames per
    /// second divided by the frames the reduce-side window folds into
    /// one emission (see `experiments/scale.rs` for the derivation).
    pub fn throughput_expected_rate(&self) -> f64 {
        let merged = (self.throughput_streams / self.group_size) as f64 * self.fps;
        let frame_interval = 1.0 / self.fps;
        let window = HadoopSpec::default().reduce_window.as_secs_f64();
        let frames_per_emit = (window / frame_interval).ceil() + 1.0;
        merged / frames_per_emit
    }

    /// Total instances at peak concurrency (for capacity sizing): all
    /// jobs overlap in the worst case.
    pub fn peak_demand(&self) -> u32 {
        // Video pipeline: 6 task types; HOP expression: 5.
        self.latency_jobs * 6 * self.latency_parallelism + 5 * self.throughput_parallelism
    }

    /// Slot capacity of the pool.
    pub fn capacity(&self) -> u32 {
        self.workers * self.slots_per_worker
    }
}

/// Build the submission for latency job `idx`: the §4.1.1 video
/// pipeline under the paper's constraint, sized per the spec.  The
/// runtime expansion the builder performs is discarded — placement is
/// the scheduler's job at submit time.
pub fn latency_submission(spec: &MultiSpec, idx: u32) -> Result<JobSubmission> {
    let vspec = VideoSpec {
        parallelism: spec.latency_parallelism,
        workers: spec.workers,
        streams: spec.latency_streams,
        group_size: spec.group_size,
        fps: spec.fps,
        constraint_ms: spec.constraint_ms,
        window_secs: spec.window_secs,
        ..VideoSpec::default()
    };
    let vj = video_job(vspec)?;
    Ok(JobSubmission {
        name: format!("video-{idx}"),
        job: vj.job,
        constraints: vj.constraints,
        task_specs: vj.task_specs,
        sources: vj.sources,
        run_for: Some(Duration::from_secs(spec.latency_job_secs)),
        manager: None, // engine default: the cluster arms full QoS
    })
}

/// Build the throughput job: the §4.1.2 Hadoop-Online expression of the
/// video workload, running *unoptimised* (static 32 KB buffers, no
/// chaining — HOP has no QoS management) under a monitoring-only
/// constraint.  Its yardstick is sink rate, not latency.
pub fn throughput_submission(spec: &MultiSpec) -> Result<JobSubmission> {
    let hspec = HadoopSpec {
        parallelism: spec.throughput_parallelism,
        workers: spec.workers,
        streams: spec.throughput_streams,
        group_size: spec.group_size,
        fps: spec.fps,
        ..HadoopSpec::default()
    };
    let hj = hadoop_online_job(hspec)?;
    Ok(JobSubmission {
        name: "hadoop-batch".to_string(),
        job: hj.job,
        constraints: hj.constraints,
        task_specs: hj.task_specs,
        sources: hj.sources,
        run_for: Some(Duration::from_secs(spec.throughput_secs)),
        manager: Some(ManagerConfig {
            enable_buffer_sizing: false,
            enable_chaining: false,
            enable_scaling: false,
            ..ManagerConfig::default()
        }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_fit_their_slot_capacity() {
        for spec in [MultiSpec::default(), MultiSpec::quick(), MultiSpec::tiny()] {
            assert!(
                spec.peak_demand() <= spec.capacity(),
                "peak demand {} exceeds capacity {}",
                spec.peak_demand(),
                spec.capacity()
            );
            // The throughput job outlives the last latency job, so the
            // contention window covers every latency job's whole life.
            let last_end =
                spec.stagger_secs * (spec.latency_jobs as u64 - 1) + spec.latency_job_secs;
            assert!(spec.throughput_secs >= last_end);
            // Warm-up leaves a real measurement tail.
            assert!(spec.warm_secs < spec.latency_job_secs);
        }
    }

    #[test]
    fn submissions_build_and_are_consistent() {
        let spec = MultiSpec::tiny();
        for i in 0..spec.latency_jobs {
            let sub = latency_submission(&spec, i).unwrap();
            assert_eq!(sub.job.vertices.len(), 6);
            assert_eq!(sub.task_specs.len(), 6);
            assert_eq!(sub.sources.len(), spec.latency_streams as usize);
            assert_eq!(sub.constraints.len(), 1);
            assert!(sub.manager.is_none());
            let demand: u32 = sub.job.vertices.iter().map(|v| v.parallelism).sum();
            assert_eq!(demand, 6 * spec.latency_parallelism);
        }
        let t = throughput_submission(&spec).unwrap();
        assert_eq!(t.job.vertices.len(), 5);
        let mgr = t.manager.unwrap();
        assert!(!mgr.enable_buffer_sizing && !mgr.enable_chaining && !mgr.enable_scaling);
    }

    #[test]
    fn expected_rates_match_the_scale_scenario_math() {
        let spec = MultiSpec::quick();
        // 16 streams / 4 per group * 4 fps = 16 merged frames/s.
        assert_eq!(spec.latency_expected_rate(), 16.0);
        // HOP window (100 ms) at 4 fps folds 2 frames per emission.
        assert_eq!(spec.throughput_expected_rate(), 8.0);
    }
}
