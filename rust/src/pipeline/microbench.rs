//! The §2.2.1 sender/receiver microbenchmark behind Fig. 2: one sender
//! producing 128-byte items at a fixed rate into an output buffer of a
//! fixed size, shipped over a TCP connection to one receiver.

use crate::graph::constraint::JobConstraint;
use crate::graph::job::{DistributionPattern, JobGraph};
use crate::graph::runtime::RuntimeGraph;
use crate::graph::sequence::JobSequence;
use crate::sim::cluster::SourceSpec;
use crate::sim::task::{KeyMap, OutBytes, Route, Semantics, TaskSpec};
use crate::util::time::Duration;
use anyhow::Result;

/// Parameters of one Fig. 2 cell.
#[derive(Debug, Clone, Copy)]
pub struct MicrobenchSpec {
    /// Data items created per second at the sender.
    pub items_per_sec: f64,
    /// Item payload (paper: 128 bytes).
    pub item_bytes: u64,
    /// TCP-flow-control bound (models the sender blocking on a saturated
    /// connection; gives the latency lower bound at high rates).
    pub throttle: Duration,
}

impl Default for MicrobenchSpec {
    fn default() -> Self {
        MicrobenchSpec {
            items_per_sec: 100.0,
            item_bytes: 128,
            throttle: Duration::from_millis(30),
        }
    }
}

/// Build the two-task job.  The sender and receiver run on different
/// workers (the paper used two machines on a 1 GBit/s link).
pub fn sender_receiver_job(
    spec: MicrobenchSpec,
) -> Result<(JobGraph, RuntimeGraph, Vec<JobConstraint>, Vec<TaskSpec>, Vec<SourceSpec>)> {
    let mut job = JobGraph::new();
    let sender = job.add_vertex("Sender", 1);
    let receiver = job.add_vertex("Receiver", 1);
    job.connect(sender, receiver, DistributionPattern::Pointwise);
    job.validate()?;
    // Two workers; the even-spread placement puts both subtask-0 tasks on
    // worker 0, so place explicitly: sender on w0, receiver on w1.
    let rg = RuntimeGraph::expand_with(&job, 2, &|jv, _| {
        crate::graph::ids::WorkerId(jv.0 % 2)
    })?;

    // A constraint keeps the channel monitored (measurement machinery on)
    // without triggering actions (the microbenchmark fixes buffer sizes).
    let seq = JobSequence::along_path(&job, &[receiver], Some(sender), None)?;
    let constraints = vec![JobConstraint::new(
        seq,
        Duration::from_secs(3600),
        Duration::from_secs(5),
    )];

    // Sender/receiver user code is a trivial produce/consume loop; the
    // measured costs are all in the channel (§2.2.1).
    let task_specs = vec![
        TaskSpec {
            semantics: Semantics::Transform,
            service: Duration::ZERO,
            out_bytes: OutBytes::Scale(1.0),
            key_map: KeyMap::Identity,
            route: Route::Pointwise,
            downstream_delay: Duration::ZERO,
        },
        TaskSpec {
            semantics: Semantics::Sink,
            service: Duration::ZERO,
            out_bytes: OutBytes::Scale(1.0),
            key_map: KeyMap::Identity,
            route: Route::Pointwise,
            downstream_delay: Duration::ZERO,
        },
    ];

    // The simulator clock has microsecond resolution: rates beyond 1e6/s
    // are expressed as batches per 1 us tick.
    let (interval, batch) = if spec.items_per_sec > 1e6 {
        (Duration::from_micros(1), (spec.items_per_sec / 1e6).round() as u32)
    } else {
        (Duration::from_secs_f64(1.0 / spec.items_per_sec), 1)
    };
    let sources = vec![SourceSpec {
        key: 0,
        target: sender,
        target_subtask: 0,
        interval,
        bytes: spec.item_bytes,
        offset: Duration::ZERO,
        throttle: Some(spec.throttle),
        batch,
    }];

    Ok((job, rg, constraints, task_specs, sources))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_two_workers_one_channel() {
        let (job, rg, constraints, specs, sources) =
            sender_receiver_job(MicrobenchSpec::default()).unwrap();
        assert_eq!(rg.vertices.len(), 2);
        assert_eq!(rg.channels.len(), 1);
        assert_ne!(rg.worker(rg.vertices[0].id), rg.worker(rg.vertices[1].id));
        assert_eq!(constraints.len(), 1);
        assert_eq!(specs.len(), job.vertices.len());
        assert_eq!(sources.len(), 1);
    }
}
