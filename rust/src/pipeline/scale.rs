//! The paper-scale comparison workload (§4.2 + §4.3.4): the "citizen
//! journalism" video pipeline and the Hadoop Online expression of the
//! same job, both sized for a 200-worker cluster with one processing
//! pipeline per host — the configuration behind the paper's headline
//! "latency improved by a factor of at least 13 while preserving high
//! data throughput" claim.
//!
//! One [`ScaleSpec`] derives *both* jobs so the comparison is apples to
//! apples: identical worker count, stream count, frame rate, group size
//! and frame geometry.  `quick()` shrinks the worker count for CI while
//! keeping every per-channel rate (streams per decoder, bytes per
//! frame) identical, so the per-hop latency mechanics — and therefore
//! the latency ratio — exercise the same code path at either size.

use super::video::VideoSpec;
use crate::baseline::hadoop::HadoopSpec;

/// Parameters of the paper-scale comparison.  Both derived jobs place
/// one pipeline per host (`parallelism == workers`, §4.3.4) and spread
/// `streams_per_worker` external streams over each.
#[derive(Debug, Clone, Copy)]
pub struct ScaleSpec {
    /// Cluster size n (§4.2: 200).
    pub workers: u32,
    /// External video streams per worker (keeps per-channel rates
    /// scale-invariant; 8 → 1600 streams at n=200).
    pub streams_per_worker: u32,
    /// Streams merged per group (§4.2: 4).
    pub group_size: u32,
    /// Frames per second per stream.
    pub fps: f64,
    /// Nephele's latency constraint l (§4.2: 300 ms).  The HOP baseline
    /// runs without QoS management, as in the paper.
    pub constraint_ms: u64,
    pub window_secs: u64,
}

impl Default for ScaleSpec {
    fn default() -> Self {
        ScaleSpec {
            workers: 200,
            streams_per_worker: 8,
            group_size: 4,
            fps: 4.0,
            constraint_ms: 300,
            window_secs: 15,
        }
    }
}

impl ScaleSpec {
    /// Reduced worker count for CI smoke runs — same streams-per-worker
    /// density, same per-channel rates, same code path.
    pub fn quick() -> ScaleSpec {
        ScaleSpec { workers: 20, ..ScaleSpec::default() }
    }

    /// Total external streams.
    pub fn streams(&self) -> u32 {
        self.workers * self.streams_per_worker
    }

    /// Merged frames produced per second in steady state (the common
    /// throughput yardstick of the two arms).
    pub fn merged_frames_per_sec(&self) -> f64 {
        (self.streams() / self.group_size) as f64 * self.fps
    }

    /// The Nephele arm: the §4.1.1 video pipeline at one pipeline per
    /// host.
    pub fn nephele(&self) -> VideoSpec {
        VideoSpec {
            parallelism: self.workers,
            workers: self.workers,
            streams: self.streams(),
            group_size: self.group_size,
            fps: self.fps,
            constraint_ms: self.constraint_ms,
            window_secs: self.window_secs,
            ..VideoSpec::default()
        }
    }

    /// The Hadoop Online arm: the §4.1.2 two-MapReduce-job expression of
    /// the same workload at the same size.
    pub fn hadoop(&self) -> HadoopSpec {
        HadoopSpec {
            parallelism: self.workers,
            workers: self.workers,
            streams: self.streams(),
            group_size: self.group_size,
            fps: self.fps,
            ..HadoopSpec::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::hadoop::hadoop_online_job;
    use crate::pipeline::video::video_job;

    #[test]
    fn default_is_the_paper_deployment() {
        let s = ScaleSpec::default();
        assert_eq!(s.workers, 200);
        assert_eq!(s.streams(), 1600);
        assert_eq!(s.merged_frames_per_sec(), 1600.0);
        let v = s.nephele();
        assert_eq!((v.parallelism, v.workers, v.streams), (200, 200, 1600));
        let h = s.hadoop();
        assert_eq!((h.parallelism, h.workers, h.streams), (200, 200, 1600));
    }

    #[test]
    fn both_arms_build_at_paper_scale() {
        let s = ScaleSpec::default();
        let vj = video_job(s.nephele()).unwrap();
        assert_eq!(vj.rg.num_workers, 200);
        assert_eq!(vj.rg.vertices.len(), 6 * 200);
        assert_eq!(vj.sources.len(), 1600);
        let hj = hadoop_online_job(s.hadoop()).unwrap();
        assert_eq!(hj.rg.num_workers, 200);
        assert_eq!(hj.rg.vertices.len(), 5 * 200);
        assert_eq!(hj.sources.len(), 1600);
    }

    #[test]
    fn quick_keeps_per_worker_density() {
        let full = ScaleSpec::default();
        let quick = ScaleSpec::quick();
        assert_eq!(quick.workers, 20);
        assert_eq!(
            quick.streams() / quick.workers,
            full.streams() / full.workers,
            "streams per worker must be scale-invariant"
        );
        let vj = video_job(quick.nephele()).unwrap();
        assert_eq!(vj.sources.len(), 160);
        hadoop_online_job(quick.hadoop()).unwrap();
    }
}
