//! Live execution: the video pipeline running with REAL compute — the
//! AOT-compiled XLA stages on the PJRT CPU client — under the same QoS
//! machinery the simulator uses.
//!
//! Topology (one OS process, real threads, real channels):
//!
//! ```text
//! producer thread ──mpsc (output-buffer batching)──► compute thread
//!  (Partitioner:                                      (Decoder, Merger,
//!   synthetic encoded                                  Overlay, Encoder as
//!   frame groups)                                      XLA executables)
//!                                                          │
//!            QosReporter ◄── real tags / task latencies ───┘
//!                │ reports
//!            QosManager ── SetBufferSize / ChainTasks ──► applied live
//! ```
//!
//! Dynamic task chaining swaps the four per-stage executables for the
//! fused `chained` artifact — the exact semantics-preserving trade the
//! paper's chaining makes (no per-stage hand-over), verified equivalent
//! in `rust/tests/integration_runtime.rs`.

pub mod pipeline;

pub use pipeline::{run_live, LiveConfig, LiveReport, StageLatencies};
