//! The live mini-cluster: producer thread + compute thread, real XLA
//! stages, real tag-based measurements, real QoS manager in the loop.

use crate::actions::Action;
use crate::graph::ids::WorkerId;
use crate::pipeline::video::{video_job, VideoSpec};
use crate::qos::manager::{ManagerConfig, QosManager};
use crate::qos::reporter::QosReporter;
use crate::qos::sample::Measurement;
use crate::qos::setup::compute_qos_setup;
use crate::runtime::StageRuntime;
use crate::util::rng::Rng;
use crate::util::time::Time;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration as StdDuration, Instant};

/// Live-run parameters (sized for a ~tens-of-seconds demo on one core).
#[derive(Debug, Clone)]
pub struct LiveConfig {
    pub artifacts_dir: PathBuf,
    /// Frame groups to push through the pipeline.
    pub frames: u32,
    /// Target production rate (frame groups per second).
    pub fps: f64,
    /// Initial output buffer size on the producer->compute channel, in
    /// bytes (encoded groups are 4 x h x w x 4 bytes of f32 coeffs).
    pub initial_buffer: u32,
    /// Latency constraint for the QoS manager (ms).
    pub constraint_ms: u64,
    /// Measurement interval (scaled down from the paper's 15 s so the
    /// demo converges in seconds).
    pub interval_ms: u64,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            // One frame group (4 x 240x320 + merge + overlay + encode at
            // 480x640) takes ~0.5-1 s of XLA CPU compute on one core:
            // pace the producer accordingly.
            frames: 48,
            fps: 0.5,
            initial_buffer: 8 * 1024 * 1024,
            constraint_ms: 700,
            interval_ms: 2_000,
        }
    }
}

/// Mean per-stage latencies (ms) over a phase of the run.
#[derive(Debug, Clone, Default)]
pub struct StageLatencies {
    pub channel_ms: f64,
    pub decode_ms: f64,
    pub merge_ms: f64,
    pub overlay_ms: f64,
    pub encode_ms: f64,
    pub total_ms: f64,
    pub frames: u32,
}

/// Outcome of a live run.
#[derive(Debug, Clone)]
pub struct LiveReport {
    /// Phase 1: unoptimized (initial buffer, staged execution).
    pub before: StageLatencies,
    /// Phase 2: after the QoS manager's actions converged.
    pub after: StageLatencies,
    pub buffer_updates: u64,
    pub chained: bool,
    pub final_buffer: u32,
    pub improvement_factor: f64,
}

/// One encoded frame group travelling the producer->compute channel.
struct EncodedGroup {
    coeffs: Vec<f32>,
    /// Tag: creation instant at the producer (real clock).
    created: Instant,
}

/// Run the live pipeline.  Everything runs on real threads with real
/// wall-clock measurements; the QoS manager receives reports and issues
/// actions exactly as on the simulated cluster.
pub fn run_live(cfg: &LiveConfig) -> Result<LiveReport> {
    // Build the logical job (m=1 pipeline) so the QoS setup is the real
    // Algorithm 1-3 output, not hand-wired.
    let spec = VideoSpec {
        parallelism: 1,
        workers: 1,
        streams: 4,
        constraint_ms: cfg.constraint_ms,
        window_secs: 1,
        ..VideoSpec::default()
    };
    let vj = video_job(spec)?;
    let setup = compute_qos_setup(&vj.job, &vj.rg, &vj.constraints)?;
    let (&mgr_worker, subgraph) = setup.managers.iter().next().context("no manager")?;
    let mut manager = QosManager::new(
        mgr_worker,
        subgraph.clone(),
        cfg.initial_buffer,
        ManagerConfig::default(),
    );
    let mut rng = Rng::new(7);
    let assignment = setup.reporters.get(&WorkerId(0)).context("no reporter")?;
    let mut reporter = QosReporter::new(
        WorkerId(0),
        crate::util::time::Duration::from_millis(cfg.interval_ms),
        assignment.interest.clone(),
        &mut rng,
    );

    // Identify the runtime elements of the (single) chain for recording.
    let chain = &subgraph.chains[0];
    let channel_in = match &chain.layers[0] {
        crate::qos::subgraph::Layer::Channels(cs) => cs[0].id,
        _ => anyhow::bail!("unexpected chain shape"),
    };
    let stage_vertices: Vec<crate::graph::ids::VertexId> =
        chain.vertices().map(|v| v.id).collect(); // D, M, O, E in order

    let rt = StageRuntime::load(&cfg.artifacts_dir)?;
    let (h, w) = (rt.manifest.frame_h, rt.manifest.frame_w);
    let (h2, w2) = (2 * h, 2 * w);
    let group_bytes = (4 * h * w * 4) as u64;

    // Prewarm every executable once so first-execution JIT warmup does
    // not pollute the phase-1 measurements.
    {
        let z_group = vec![0f32; 4 * h * w];
        let z_frame = vec![0f32; h * w];
        let z_merged = vec![0f32; h2 * w2];
        let _ = rt.stage("decoder")?.run(&[&z_frame])?;
        let _ = rt.stage("merger")?.run(&[&z_group])?;
        let _ = rt.stage("overlay")?.run(&[&z_merged, &z_merged, &z_merged])?;
        let _ = rt.stage("encoder")?.run(&[&z_merged])?;
        let _ = rt.stage("chained")?.run(&[&z_group, &z_merged, &z_merged])?;
    }

    // Marquee overlay inputs (constant across frames).
    let image: Vec<f32> = (0..h2 * w2).map(|i| (i % 97) as f32).collect();
    let mut alpha = vec![0f32; h2 * w2];
    for r in (h2 - 16)..h2 {
        for c in 0..w2 {
            alpha[r * w2 + c] = 0.6;
        }
    }

    // Producer thread: synthesises encoded frame groups at cfg.fps and
    // ships them through an output-buffer-batched channel.  The buffer
    // size is controlled by the QoS manager via a shared atomic.
    let buffer_size = std::sync::Arc::new(std::sync::atomic::AtomicU32::new(cfg.initial_buffer));
    let (tx, rx) = mpsc::sync_channel::<Vec<EncodedGroup>>(64);
    let producer_buffer = buffer_size.clone();
    let frames = cfg.frames;
    let fps = cfg.fps;
    let producer = std::thread::spawn(move || {
        let mut prng = Rng::new(42);
        let mut batch: Vec<EncodedGroup> = Vec::new();
        let mut batch_bytes = 0u64;
        let period = StdDuration::from_secs_f64(1.0 / fps);
        for _ in 0..frames {
            let t0 = Instant::now();
            let coeffs: Vec<f32> = (0..4 * h * w)
                .map(|_| (prng.below(41) as f32) - 20.0)
                .collect();
            batch_bytes += group_bytes;
            batch.push(EncodedGroup { coeffs, created: Instant::now() });
            // Flush when the output buffer reaches its capacity limit.
            if batch_bytes >= producer_buffer.load(std::sync::atomic::Ordering::Relaxed) as u64 {
                if tx.send(std::mem::take(&mut batch)).is_err() {
                    return;
                }
                batch_bytes = 0;
            }
            let spent = t0.elapsed();
            if spent < period {
                std::thread::sleep(period - spent);
            }
        }
        if !batch.is_empty() {
            let _ = tx.send(batch);
        }
    });

    // Compute thread (this thread): runs the stages, measures, reports.
    let start = Instant::now();
    let to_virtual = |i: Instant| Time::from_secs_f64(i.duration_since(start).as_secs_f64());
    let mut chained = false;
    let mut buffer_updates = 0u64;
    let mut phase1 = StageLatencies::default();
    let mut phase2 = StageLatencies::default();
    let mut last_flush = Instant::now();
    let mut batch_fill_start: Option<Instant> = None;

    let record_phase = |p: &mut StageLatencies,
                        ch: f64,
                        d: f64,
                        m: f64,
                        o: f64,
                        e: f64| {
        let n = p.frames as f64;
        let upd = |acc: &mut f64, v: f64| *acc = (*acc * n + v) / (n + 1.0);
        upd(&mut p.channel_ms, ch);
        upd(&mut p.decode_ms, d);
        upd(&mut p.merge_ms, m);
        upd(&mut p.overlay_ms, o);
        upd(&mut p.encode_ms, e);
        upd(&mut p.total_ms, ch + d + m + o + e);
        p.frames += 1;
    };

    while let Ok(batch) = rx.recv() {
        let batch_arrival = Instant::now();
        if batch_fill_start.is_none() {
            batch_fill_start = Some(batch_arrival);
        }
        // Output buffer lifetime: time from the first item's creation to
        // the flush (approximated by first item created -> batch arrival).
        if let Some(first) = batch.first() {
            let oblt = batch_arrival.duration_since(first.created).as_secs_f64() * 1e6;
            reporter.record(Measurement::output_buffer_lifetime(channel_in, oblt));
        }
        for group in batch {
            let enter = Instant::now();
            let channel_us = enter.duration_since(group.created).as_secs_f64() * 1e6;
            reporter.record(Measurement::channel_latency(channel_in, channel_us));

            let (d_ms, m_ms, o_ms, e_ms) = if chained {
                let t0 = Instant::now();
                let _out = rt.stage("chained")?.run(&[&group.coeffs, &image, &alpha])?;
                let total = t0.elapsed().as_secs_f64() * 1e3;
                // The fused executable is one task: attribute its time to
                // the stages proportionally for reporting continuity.
                (total * 0.4, total * 0.1, total * 0.2, total * 0.3)
            } else {
                let t0 = Instant::now();
                let mut frames_buf = Vec::with_capacity(4 * h * w);
                for g in 0..4 {
                    frames_buf.extend(
                        rt.stage("decoder")?
                            .run(&[&group.coeffs[g * h * w..(g + 1) * h * w]])?,
                    );
                }
                let t1 = Instant::now();
                let merged = rt.stage("merger")?.run(&[&frames_buf])?;
                let t2 = Instant::now();
                let composited = rt.stage("overlay")?.run(&[&merged, &image, &alpha])?;
                let t3 = Instant::now();
                let _encoded = rt.stage("encoder")?.run(&[&composited])?;
                let t4 = Instant::now();
                (
                    t1.duration_since(t0).as_secs_f64() * 1e3,
                    t2.duration_since(t1).as_secs_f64() * 1e3,
                    t3.duration_since(t2).as_secs_f64() * 1e3,
                    t4.duration_since(t3).as_secs_f64() * 1e3,
                )
            };

            // Task latency + CPU reports for the QoS manager.
            let stage_ms = [d_ms, m_ms, o_ms, e_ms];
            for (v, ms) in stage_vertices.iter().zip(stage_ms) {
                reporter.record(Measurement::task_latency(*v, ms * 1e3));
                reporter.record(Measurement::task_cpu(*v, (ms / 1e3 * fps).min(0.2)));
            }
            // Channels between the (colocated) stages: direct hand-over.
            for c in chain.channels().skip(1) {
                reporter.record(Measurement::channel_latency(c.id, 1.0));
                reporter.record(Measurement::output_buffer_lifetime(c.id, 1.0));
            }

            let phase = if chained || buffer_updates > 0 { &mut phase2 } else { &mut phase1 };
            record_phase(phase, channel_us / 1e3, d_ms, m_ms, o_ms, e_ms);
        }

        // QoS control loop, once per interval.
        if last_flush.elapsed() >= StdDuration::from_millis(cfg.interval_ms) {
            last_flush = Instant::now();
            let now = to_virtual(last_flush);
            for report in reporter.flush_due(now) {
                manager.ingest(&report);
            }
            for action in manager.act(now) {
                match action {
                    Action::SetBufferSize { size, channel, .. } if channel == channel_in => {
                        buffer_size.store(size, std::sync::atomic::Ordering::Relaxed);
                        reporter.note_buffer_update(channel, size);
                        buffer_updates += 1;
                    }
                    Action::SetBufferSize { .. } => {}
                    Action::ChainTasks { .. } => {
                        chained = true;
                    }
                    // The live mini-cluster is a fixed 1-worker pipeline:
                    // elastic scaling and migration do not apply.
                    Action::ScaleTasks { .. } => {}
                    Action::MigrateInstance { .. } => {}
                    Action::Unresolvable { .. } => {}
                }
            }
        }
    }
    producer.join().ok();

    let improvement = if phase2.frames > 0 && phase2.total_ms > 0.0 {
        phase1.total_ms / phase2.total_ms
    } else {
        1.0
    };
    Ok(LiveReport {
        before: phase1,
        after: phase2,
        buffer_updates,
        chained,
        final_buffer: buffer_size.load(std::sync::atomic::Ordering::Relaxed),
        improvement_factor: improvement,
    })
}
