//! # Nephele Streaming (reproduction)
//!
//! A production-style reproduction of *"Nephele Streaming: Stream
//! Processing under QoS Constraints at Scale"* (Lohrmann, Warneke, Kao;
//! Cluster Computing 2013).
//!
//! The crate implements a massively-parallel streaming engine in the
//! paper's architecture — master/worker, per-task threads, output
//! buffers, input queues — plus the paper's contribution: a fully
//! distributed QoS-management scheme (QoS Reporters and Managers,
//! Algorithms 1–3) with two runtime countermeasures, **adaptive output
//! buffer sizing** and **dynamic task chaining**.
//!
//! Two execution substrates share all QoS logic:
//! * [`sim`] — a discrete-event cluster simulator that runs the paper's
//!   full 200-node / m=800 / 6400-stream evaluation on one core, and
//! * [`live`] — a real multi-threaded pipeline whose compute-bound tasks
//!   execute AOT-compiled XLA executables (JAX/Pallas → HLO text → PJRT)
//!   via [`runtime`].
//!
//! See `DESIGN.md` for the paper→module map and `EXPERIMENTS.md` for the
//! reproduced figures.

pub mod actions;
pub mod baseline;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod graph;
pub mod lint;
pub mod live;
pub mod pipeline;
pub mod qos;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod telemetry;
pub mod util;
