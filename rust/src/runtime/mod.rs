//! PJRT runtime bridge: loads the AOT-compiled HLO-text artifacts
//! produced by `python/compile/aot.py` and executes them from the Rust
//! request path.  Python never runs at request time.
//!
//! Pattern (smoke-verified in /opt/xla-example/load_hlo):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.
//! HLO *text* is the interchange format — jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that the crate's XLA (0.5.1) rejects; the text
//! parser reassigns ids.

#[cfg(feature = "xla")]
pub mod executor;

// Offline builds (the default) get an API-compatible stub: the rest of
// the crate — notably the live engine — compiles unchanged, and any
// attempt to execute a stage fails with a clear message.
#[cfg(not(feature = "xla"))]
#[path = "executor_stub.rs"]
pub mod executor;

pub use executor::{StageExecutor, StageRuntime};
