//! Artifact registry + executor pool: compile every stage once at
//! startup, then execute with plain `f32` buffers on the hot path.

use crate::util::manifest::{Manifest, StageSpec};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One compiled pipeline stage.
pub struct StageExecutor {
    pub spec: StageSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl StageExecutor {
    /// Execute the stage on `inputs` (one flat `f32` slice per declared
    /// input shape).  Returns the flattened `f32` output.
    pub fn run(&self, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        if inputs.len() != self.spec.input_shapes.len() {
            bail!(
                "stage {}: got {} inputs, expected {}",
                self.spec.name,
                inputs.len(),
                self.spec.input_shapes.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, shape) in inputs.iter().zip(&self.spec.input_shapes) {
            let want: usize = shape.iter().product();
            if buf.len() != want {
                bail!(
                    "stage {}: input has {} elements, shape {:?} wants {}",
                    self.spec.name,
                    buf.len(),
                    shape,
                    want
                );
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(buf).reshape(&dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Total expected output element count is data-dependent; helper for
    /// the known stage geometry.
    pub fn input_elems(&self) -> usize {
        self.spec.input_elems()
    }
}

/// All compiled stages of the artifact directory.
pub struct StageRuntime {
    pub manifest: Manifest,
    stages: BTreeMap<String, StageExecutor>,
}

impl StageRuntime {
    /// Load `manifest.txt` from `dir` and compile every stage on the
    /// PJRT CPU client.
    pub fn load(dir: &Path) -> Result<StageRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut stages = BTreeMap::new();
        for (name, spec) in &manifest.stages {
            let proto = xla::HloModuleProto::from_text_file(
                spec.file
                    .to_str()
                    .context("artifact path not valid UTF-8")?,
            )
            .with_context(|| format!("parsing HLO text for stage {name}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling stage {name}"))?;
            stages.insert(name.clone(), StageExecutor { spec: spec.clone(), exe });
        }
        Ok(StageRuntime { manifest, stages })
    }

    pub fn stage(&self, name: &str) -> Result<&StageExecutor> {
        self.stages
            .get(name)
            .with_context(|| format!("stage {name:?} not loaded"))
    }

    pub fn stage_names(&self) -> impl Iterator<Item = &str> {
        self.stages.keys().map(|s| s.as_str())
    }
}
