//! Offline stub of the PJRT executor, compiled when the `xla` feature is
//! disabled.  Keeps the [`StageRuntime`]/[`StageExecutor`] API so the live
//! engine and its callers compile; any attempt to actually load or run a
//! stage fails with a clear message.

use crate::util::manifest::{Manifest, StageSpec};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One compiled pipeline stage (stub: never constructible at runtime).
pub struct StageExecutor {
    pub spec: StageSpec,
}

impl StageExecutor {
    /// Stub: always fails — there is no PJRT client in this build.
    pub fn run(&self, _inputs: &[&[f32]]) -> Result<Vec<f32>> {
        bail!(
            "stage {}: nephele was built without the `xla` feature; \
             rebuild with `--features xla` (and vendored xla crate) to execute stages",
            self.spec.name
        );
    }

    /// Total expected input element count (mirrors the real executor).
    pub fn input_elems(&self) -> usize {
        self.spec.input_elems()
    }
}

/// All compiled stages of the artifact directory (stub).
pub struct StageRuntime {
    pub manifest: Manifest,
    stages: BTreeMap<String, StageExecutor>,
}

impl StageRuntime {
    /// Stub: always fails — loading artifacts requires the PJRT client.
    pub fn load(dir: &Path) -> Result<StageRuntime> {
        bail!(
            "cannot load XLA artifacts from {}: nephele was built without the \
             `xla` feature (see DESIGN.md, offline build notes)",
            dir.display()
        );
    }

    pub fn stage(&self, name: &str) -> Result<&StageExecutor> {
        self.stages
            .get(name)
            .with_context(|| format!("stage {name:?} not loaded"))
    }

    pub fn stage_names(&self) -> impl Iterator<Item = &str> {
        self.stages.keys().map(|s| s.as_str())
    }
}
