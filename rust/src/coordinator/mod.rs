//! Master-side coordination: worker-failure detection.
//!
//! The paper's master node already receives the whole QoS control-plane
//! traffic stream (reports, actions, failed-optimisation notices).  The
//! [`FailureDetector`] piggybacks on it: every worker with a QoS
//! Reporter role flushes roughly once per measurement interval, so a
//! worker whose reports stop arriving for a configurable number of
//! intervals is declared failed.  What happens next is the recovery
//! policy's business ([`crate::config::RecoveryConfig`]): redeploy the
//! dead instances and replay from the `pin_unchainable` materialisation
//! points, or merely unregister the worker.

use crate::graph::ids::WorkerId;
use crate::util::time::{Duration, Time};
use std::collections::{BTreeMap, BTreeSet};

/// Tracks report liveness per reporter-hosting worker.
#[derive(Debug, Default)]
pub struct FailureDetector {
    timeout: Duration,
    last_seen: BTreeMap<WorkerId, Time>,
    /// Workers already declared failed (never re-reported).
    confirmed: BTreeSet<WorkerId>,
}

impl FailureDetector {
    /// `detection_intervals` missed measurement intervals declare a
    /// worker failed; half an interval of slack absorbs report phase
    /// offsets and control-plane delay.
    pub fn new(measurement_interval: Duration, detection_intervals: u32) -> FailureDetector {
        let micros = measurement_interval.as_micros();
        let timeout = Duration::from_micros(micros * detection_intervals as u64 + micros / 2);
        FailureDetector { timeout, last_seen: BTreeMap::new(), confirmed: BTreeSet::new() }
    }

    pub fn timeout(&self) -> Duration {
        self.timeout
    }

    /// Re-sync the monitored set with the current QoS setup (cluster
    /// construction and every rebuild): workers gaining a reporter role
    /// start their grace period now, workers losing it are dropped.
    pub fn track<I: IntoIterator<Item = WorkerId>>(&mut self, reporters: I, now: Time) {
        let keep: BTreeSet<WorkerId> = reporters.into_iter().collect();
        self.last_seen.retain(|w, _| keep.contains(w));
        for w in keep {
            if !self.confirmed.contains(&w) {
                self.last_seen.entry(w).or_insert(now);
            }
        }
    }

    /// A report from `worker` passed through the master at `now`.
    pub fn note(&mut self, worker: WorkerId, now: Time) {
        if let Some(t) = self.last_seen.get_mut(&worker) {
            if now > *t {
                *t = now;
            }
        }
    }

    /// Monitored workers silent past the timeout and not yet confirmed.
    pub fn silent(&self, now: Time) -> Vec<WorkerId> {
        self.last_seen
            .iter()
            .filter(|&(w, &t)| now.since(t) > self.timeout && !self.confirmed.contains(w))
            .map(|(&w, _)| w)
            .collect()
    }

    /// Mark a worker as handled: it is no longer monitored and will not
    /// be reported silent again.
    pub fn confirm(&mut self, worker: WorkerId) {
        self.confirmed.insert(worker);
        self.last_seen.remove(&worker);
    }

    pub fn is_confirmed(&self, worker: WorkerId) -> bool {
        self.confirmed.contains(&worker)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det() -> FailureDetector {
        FailureDetector::new(Duration::from_secs(15), 2)
    }

    #[test]
    fn timeout_includes_half_interval_slack() {
        assert_eq!(det().timeout(), Duration::from_micros(37_500_000));
    }

    #[test]
    fn silent_worker_is_detected_after_timeout() {
        let mut d = det();
        let t0 = Time::from_secs_f64(10.0);
        d.track([WorkerId(0), WorkerId(1)], t0);
        d.note(WorkerId(0), Time::from_secs_f64(40.0));
        // Worker 1 never reported after t0: silent once the timeout is up.
        assert!(d.silent(Time::from_secs_f64(45.0)).is_empty());
        assert_eq!(d.silent(Time::from_secs_f64(48.0)), vec![WorkerId(1)]);
    }

    #[test]
    fn reports_keep_a_worker_alive() {
        let mut d = det();
        d.track([WorkerId(3)], Time::ZERO);
        for s in [15.0, 30.0, 45.0, 60.0] {
            d.note(WorkerId(3), Time::from_secs_f64(s));
            assert!(d.silent(Time::from_secs_f64(s + 20.0)).is_empty());
        }
    }

    #[test]
    fn confirm_is_terminal_and_survives_retrack() {
        let mut d = det();
        d.track([WorkerId(2)], Time::ZERO);
        assert_eq!(d.silent(Time::from_secs_f64(60.0)), vec![WorkerId(2)]);
        d.confirm(WorkerId(2));
        assert!(d.is_confirmed(WorkerId(2)));
        assert!(d.silent(Time::from_secs_f64(120.0)).is_empty());
        // A rebuild that (spuriously) lists the dead worker again must
        // not resurrect it.
        d.track([WorkerId(2)], Time::from_secs_f64(120.0));
        assert!(d.silent(Time::from_secs_f64(400.0)).is_empty());
    }

    #[test]
    fn untracked_workers_are_never_reported() {
        let mut d = det();
        d.note(WorkerId(9), Time::from_secs_f64(5.0));
        assert!(d.silent(Time::from_secs_f64(500.0)).is_empty());
    }

    #[test]
    fn retrack_starts_grace_for_new_workers_only() {
        let mut d = det();
        d.track([WorkerId(0)], Time::ZERO);
        // Worker 1 appears at a rebuild much later: its grace starts then.
        d.track([WorkerId(0), WorkerId(1)], Time::from_secs_f64(100.0));
        let silent = d.silent(Time::from_secs_f64(110.0));
        assert_eq!(silent, vec![WorkerId(0)], "old worker is overdue, new one is not");
    }
}
