//! `nephele-lint` — standalone entry point for the in-repo static
//! analysis pass, equivalent to `nephele lint` but buildable and
//! runnable on its own (CI invokes this binary so the gate does not
//! depend on the full coordinator CLI linking).
//!
//! See `nephele::lint` for the rules and `DESIGN.md` §11 for their
//! semantics, the suppression syntax and the ratchet workflow.

use anyhow::Result;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    nephele::lint::cli_main(&argv)
}
