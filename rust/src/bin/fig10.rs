//! Regenerates Fig. 10 (§4.3.4): latency of the Hadoop Online baseline
//! (80 video streams, m=10, 100 ms reduce window).
//!
//! Usage: `fig10 [--secs N] [--seed N]`

use nephele::baseline::hadoop::HadoopSpec;
use nephele::experiments::hadoop::run_hadoop_online;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut secs = 300;
    let mut seed = 42;
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--secs" => {
                secs = argv[i + 1].parse()?;
                i += 2;
            }
            "--seed" => {
                seed = argv[i + 1].parse()?;
                i += 2;
            }
            other => anyhow::bail!("unknown argument {other:?}"),
        }
    }
    let report = run_hadoop_online(HadoopSpec::default(), secs, seed)?;
    println!("== Fig. 10 — latency in Hadoop Online ==");
    print!("{}", report.breakdown.render());
    println!(
        "ground-truth e2e mean: {} ms | delivered: {}",
        report.e2e_mean_ms.map_or("n/a".into(), |v| format!("{v:.1}")),
        report.items_delivered
    );
    Ok(())
}
