//! Regenerates Fig. 9: latency with adaptive output buffer sizing and
//! dynamic task chaining (§4.3.3).

#[path = "figbin_common.rs"]
mod figbin;

use nephele::experiments::video_scenarios::{run_video_scenario, Scenario};

fn main() -> anyhow::Result<()> {
    let (spec, cfg, secs, verbose) = figbin::video_args(std::env::args(), 900)?;
    let report = run_video_scenario(Scenario::BuffersAndChaining, spec, cfg, secs, 30, verbose)?;
    figbin::print_scenario_summary(&report);
    Ok(())
}
