//! Regenerates Fig. 2 (§2.2.1): item latency and throughput of the
//! sender/receiver microbenchmark, swept over data creation rate and
//! output buffer size (including the flush-every-item baseline).
//!
//! Usage: `fig2 [--low-rate-secs N] [--seed N]`

use nephele::experiments::fig2::{fig2_sweep, render};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut low_secs = 3600;
    let mut seed = 42;
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--low-rate-secs" => {
                low_secs = argv[i + 1].parse()?;
                i += 2;
            }
            "--seed" => {
                seed = argv[i + 1].parse()?;
                i += 2;
            }
            other => anyhow::bail!("unknown argument {other:?}"),
        }
    }
    let cells = fig2_sweep(low_secs, seed)?;
    print!("{}", render(&cells));
    Ok(())
}
