//! Load-surge scenario driver: the elastic-scaling countermeasure end to
//! end.  Runs the surge job (base load -> surge -> overload) with the
//! requested countermeasure set and prints the recovery summary.
//!
//! Usage: `surge [--secs N] [--seed N] [--scaling true|false]
//!               [--surge-at SECS] [--constraint-ms N] [--quiet]`

#[path = "figbin_common.rs"]
mod figbin;

use nephele::experiments::load_surge::run_load_surge;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (spec, cfg, secs, scaling, verbose) = figbin::surge_args(&argv, 360)?;
    let report = run_load_surge(spec, cfg, scaling, secs, verbose)?;
    figbin::print_surge_summary(&report);
    Ok(())
}
