//! Regenerates Fig. 7: latency without optimizations (§4.3.1).
//!
//! Usage: `fig7 [--scale small|paper] [--secs N] [--seed N] [--quiet]`

#[path = "figbin_common.rs"]
mod figbin;

use nephele::experiments::video_scenarios::{run_video_scenario, Scenario};

fn main() -> anyhow::Result<()> {
    let (spec, cfg, secs, verbose) = figbin::video_args(std::env::args(), 300)?;
    let report = run_video_scenario(Scenario::Unoptimized, spec, cfg, secs, 30, verbose)?;
    figbin::print_scenario_summary(&report);
    Ok(())
}
