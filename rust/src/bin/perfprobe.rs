//! Profiling driver: a fixed high-event-rate sim workload for `perf`.
//! Engine errors propagate as a non-zero exit instead of a panic.
use anyhow::Result;
use nephele::config::EngineConfig;
use nephele::pipeline::video::{video_job, VideoSpec};
use nephele::sim::cluster::SimCluster;
use nephele::util::time::Duration;

fn main() -> Result<()> {
    let secs: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(600);
    let vj = video_job(VideoSpec::small())?;
    let mut cluster = SimCluster::new(
        vj.job, vj.rg, &vj.constraints, vj.task_specs, vj.sources,
        EngineConfig::default().fully_optimized(),
    )?;
    let t0 = std::time::Instant::now();
    cluster.run(Duration::from_secs(secs), None)?;
    let ev = cluster.stats.events_processed;
    eprintln!("{} events in {:.3}s = {:.2} M ev/s",
        ev, t0.elapsed().as_secs_f64(), ev as f64 / t0.elapsed().as_secs_f64() / 1e6);
    Ok(())
}
