//! Shared CLI plumbing for the figure binaries (no clap in the offline
//! build; a tiny hand-rolled parser suffices).

// Each binary includes this module via #[path] and uses a subset of it.
#![allow(unused_imports, dead_code)]

use anyhow::{bail, Result};
use nephele::config::EngineConfig;
use nephele::experiments::video_scenarios::{Scenario, ScenarioReport};
use nephele::pipeline::video::VideoSpec;
use nephele::sched::PlacementPolicy;

/// The subcommand set, shared by `nephele info` and the usage error so
/// the two cannot drift.
pub const SUBCOMMANDS: &str =
    "sim-video | sim-meter | sim-surge | sim-failover | sim-scale | sim-multi | live | lint | info";

/// Telemetry export destinations, shared by the scenario drivers:
/// `--trace-out FILE` (Chrome trace-event JSON, Perfetto-loadable),
/// `--metrics-out FILE` (Prometheus-style text), `--journal-out FILE`
/// (JSONL decision journal).  All optional; nothing is written unless
/// the flag is given.
#[derive(Debug, Clone, Default)]
pub struct TelemetryOut {
    pub trace_out: Option<std::path::PathBuf>,
    pub metrics_out: Option<std::path::PathBuf>,
    pub journal_out: Option<std::path::PathBuf>,
}

impl TelemetryOut {
    /// Absorb one flag/value pair if it is one of ours.
    pub fn accept(&mut self, flag: &str, value: &str) -> bool {
        match flag {
            "--trace-out" => self.trace_out = Some(value.into()),
            "--metrics-out" => self.metrics_out = Some(value.into()),
            "--journal-out" => self.journal_out = Some(value.into()),
            _ => return false,
        }
        true
    }

    /// Write the collected `(label, snapshot)` sections to whichever
    /// destinations were requested.  Sections become Chrome trace
    /// "processes", Prometheus comment-delimited blocks, and JSONL
    /// section-header records respectively.
    pub fn write(
        &self,
        sections: &[(String, nephele::telemetry::TelemetrySnapshot)],
    ) -> Result<()> {
        if let Some(path) = &self.trace_out {
            let journals: Vec<(String, &nephele::telemetry::Journal)> =
                sections.iter().map(|(l, s)| (l.clone(), &s.journal)).collect();
            std::fs::write(path, nephele::telemetry::chrome_trace(&journals))?;
        }
        if let Some(path) = &self.metrics_out {
            let mut out = String::new();
            for (label, s) in sections {
                out.push_str(&format!("# section: {label} (journal {})\n", s.journal_digest));
                out.push_str(&s.metrics_text);
            }
            std::fs::write(path, out)?;
        }
        if let Some(path) = &self.journal_out {
            let mut out = String::new();
            for (label, s) in sections {
                // Keep every line valid JSON: the section header is a
                // record too, not a comment.
                out.push_str(&format!(
                    "{{\"section\":\"{}\",\"digest\":\"{}\",\"records\":{}}}\n",
                    nephele::telemetry::export::json_escape(label),
                    s.journal_digest,
                    s.journal.len(),
                ));
                out.push_str(&nephele::telemetry::journal_jsonl(&s.journal));
            }
            std::fs::write(path, out)?;
        }
        Ok(())
    }
}

/// Parse `--scale small|paper --secs N --seed N --quiet --constraint-ms N`.
#[allow(dead_code)]
pub fn video_args(
    args: impl Iterator<Item = String>,
    default_secs: u64,
) -> Result<(VideoSpec, EngineConfig, u64, bool)> {
    let mut spec = VideoSpec::default();
    let mut cfg = EngineConfig::default();
    let mut secs = default_secs;
    let mut verbose = true;
    let argv: Vec<String> = args.skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        let need = |i: usize| -> Result<&String> {
            argv.get(i + 1)
                .ok_or_else(|| anyhow::anyhow!("missing value after {}", argv[i]))
        };
        match argv[i].as_str() {
            "--scale" => {
                spec = match need(i)?.as_str() {
                    "small" => VideoSpec::small(),
                    "paper" => VideoSpec::default(),
                    other => bail!("unknown scale {other:?} (small|paper)"),
                };
                i += 2;
            }
            "--secs" => {
                secs = need(i)?.parse()?;
                i += 2;
            }
            "--seed" => {
                cfg.seed = need(i)?.parse()?;
                i += 2;
            }
            "--constraint-ms" => {
                spec.constraint_ms = need(i)?.parse()?;
                i += 2;
            }
            "--quiet" => {
                verbose = false;
                i += 1;
            }
            "--help" | "-h" => {
                println!(
                    "usage: [--scale small|paper] [--secs N] [--seed N] [--constraint-ms N] [--quiet]"
                );
                std::process::exit(0);
            }
            other => bail!("unknown argument {other:?}"),
        }
    }
    Ok((spec, cfg, secs, verbose))
}

/// Shared flag loop of the scenario drivers: handles the common
/// `--secs N --seed N --quiet --help` set, hands every flag listed in
/// `scenario_flags` (all value-taking) with its value to `handle`, and
/// rejects anything else.  Returns `(cfg, secs, verbose)`.
fn scenario_args(
    argv: &[String],
    default_secs: u64,
    usage: &str,
    scenario_flags: &[&str],
    handle: &mut dyn FnMut(&str, &str) -> Result<()>,
) -> Result<(EngineConfig, u64, bool)> {
    let mut cfg = EngineConfig::default();
    let mut secs = default_secs;
    let mut verbose = true;
    let mut i = 0;
    while i < argv.len() {
        let need = |i: usize| -> Result<&String> {
            argv.get(i + 1)
                .ok_or_else(|| anyhow::anyhow!("missing value after {}", argv[i]))
        };
        match argv[i].as_str() {
            "--secs" => {
                secs = need(i)?.parse()?;
                i += 2;
            }
            "--seed" => {
                cfg.seed = need(i)?.parse()?;
                i += 2;
            }
            "--quiet" => {
                verbose = false;
                i += 1;
            }
            "--help" | "-h" => {
                println!("{usage}");
                std::process::exit(0);
            }
            flag if scenario_flags.contains(&flag) => {
                handle(flag, need(i)?.as_str())?;
                i += 2;
            }
            other => bail!("unknown argument {other:?}"),
        }
    }
    Ok((cfg, secs, verbose))
}

/// Parse `nephele sim-video`'s arguments (`argv` holds only the flags):
/// `--scale small|paper --scenario unopt|buffers|full --secs N --seed N
/// --constraint-ms N --quiet`.
/// Returns `(spec, cfg, scenario, secs, verbose)`.
pub fn video_scenario_args(
    argv: &[String],
    default_secs: u64,
) -> Result<(VideoSpec, EngineConfig, Scenario, u64, bool)> {
    let mut spec = VideoSpec::small();
    let mut scenario = Scenario::BuffersAndChaining;
    let (cfg, secs, verbose) = scenario_args(
        argv,
        default_secs,
        "usage: [--scale small|paper] [--scenario unopt|buffers|full] [--secs N] \
         [--seed N] [--constraint-ms N] [--quiet]",
        &["--scale", "--scenario", "--constraint-ms"],
        &mut |flag, value| {
            match flag {
                "--scale" => {
                    spec = match value {
                        "small" => VideoSpec::small(),
                        "paper" => VideoSpec::default(),
                        other => bail!("unknown scale {other:?} (small|paper)"),
                    }
                }
                "--scenario" => {
                    scenario = match value {
                        "unopt" => Scenario::Unoptimized,
                        "buffers" => Scenario::AdaptiveBuffers,
                        "full" => Scenario::BuffersAndChaining,
                        other => bail!("unknown scenario {other:?} (unopt|buffers|full)"),
                    }
                }
                "--constraint-ms" => spec.constraint_ms = value.parse()?,
                _ => unreachable!("unlisted scenario flag {flag}"),
            }
            Ok(())
        },
    )?;
    Ok((spec, cfg, scenario, secs, verbose))
}

/// Parse `nephele sim-meter`'s arguments (`argv` holds only the flags):
/// `--secs N --seed N --optimized true|false --quiet`.
/// Returns `(cfg, secs, optimized, verbose)`.
pub fn meter_args(argv: &[String], default_secs: u64) -> Result<(EngineConfig, u64, bool, bool)> {
    let mut optimized = true;
    let (cfg, secs, verbose) = scenario_args(
        argv,
        default_secs,
        "usage: [--secs N] [--seed N] [--optimized true|false] [--quiet]",
        &["--optimized"],
        &mut |flag, value| {
            match flag {
                "--optimized" => optimized = value.parse()?,
                _ => unreachable!("unlisted scenario flag {flag}"),
            }
            Ok(())
        },
    )?;
    Ok((cfg, secs, optimized, verbose))
}

/// Parse `nephele live`'s arguments (`argv` holds only the flags):
/// `--frames N --fps F --artifacts DIR --constraint-ms N`.
pub fn live_args(argv: &[String]) -> Result<nephele::live::LiveConfig> {
    let mut cfg = nephele::live::LiveConfig::default();
    let mut i = 0;
    while i < argv.len() {
        let need = |i: usize| -> Result<&String> {
            argv.get(i + 1)
                .ok_or_else(|| anyhow::anyhow!("missing value after {}", argv[i]))
        };
        match argv[i].as_str() {
            "--frames" => {
                cfg.frames = need(i)?.parse()?;
                i += 2;
            }
            "--fps" => {
                cfg.fps = need(i)?.parse()?;
                i += 2;
            }
            "--artifacts" => {
                cfg.artifacts_dir = need(i)?.as_str().into();
                i += 2;
            }
            "--constraint-ms" => {
                cfg.constraint_ms = need(i)?.parse()?;
                i += 2;
            }
            "--help" | "-h" => {
                println!("usage: [--frames N] [--fps F] [--artifacts DIR] [--constraint-ms N]");
                std::process::exit(0);
            }
            other => bail!("unknown argument {other:?}"),
        }
    }
    Ok(cfg)
}

/// Parse `nephele sim-multi`'s arguments (`argv` holds only the flags):
/// `--quick --seed N --policy spread|pack|least-loaded --tolerance F
/// --threads N --phase base|admission|fairness|preempt|migrate|all
/// --quiet`.
/// Returns `(spec, cfg, policies, tolerance, verbose, phases, tel)`.
/// Without `--policy`, both standard policies (spread, pack) are run
/// and verified; `--policy` narrows the set to one (useful for
/// exploring `least-loaded`).  Without `--phase`, every phase runs —
/// the base contention scenario plus the admission/fairness/preemption
/// /migration governance phases.
pub fn multi_args(
    argv: &[String],
) -> Result<(
    nephele::pipeline::multi::MultiSpec,
    EngineConfig,
    Vec<PlacementPolicy>,
    f64,
    bool,
    Vec<nephele::experiments::multi::Phase>,
    TelemetryOut,
)> {
    let mut cfg = EngineConfig::default();
    let mut quick = false;
    let mut policies: Option<Vec<PlacementPolicy>> = None;
    let mut tolerance = 1.1;
    let mut verbose = true;
    let mut phases: Option<Vec<nephele::experiments::multi::Phase>> = None;
    let mut tel = TelemetryOut::default();
    let mut i = 0;
    while i < argv.len() {
        let need = |i: usize| -> Result<&String> {
            argv.get(i + 1)
                .ok_or_else(|| anyhow::anyhow!("missing value after {}", argv[i]))
        };
        match argv[i].as_str() {
            "--quick" => {
                quick = true;
                i += 1;
            }
            "--seed" => {
                cfg.seed = need(i)?.parse()?;
                i += 2;
            }
            "--policy" => {
                let value = need(i)?;
                policies = Some(vec![PlacementPolicy::parse(value).ok_or_else(|| {
                    anyhow::anyhow!("unknown policy {value:?} (spread|pack|least-loaded)")
                })?]);
                i += 2;
            }
            "--tolerance" => {
                tolerance = need(i)?.parse()?;
                i += 2;
            }
            "--threads" => {
                cfg.threads = need(i)?.parse()?;
                i += 2;
            }
            "--phase" => {
                let value = need(i)?;
                phases =
                    Some(nephele::experiments::multi::Phase::parse(value).ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown phase {value:?} \
                             (base|admission|fairness|preempt|migrate|all)"
                        )
                    })?);
                i += 2;
            }
            "--quiet" => {
                verbose = false;
                i += 1;
            }
            flag @ ("--trace-out" | "--metrics-out" | "--journal-out") => {
                tel.accept(flag, need(i)?);
                i += 2;
            }
            "--help" | "-h" => {
                println!(
                    "usage: [--quick] [--seed N] [--policy spread|pack|least-loaded] \
                     [--tolerance F] [--threads N] \
                     [--phase base|admission|fairness|preempt|migrate|all] \
                     [--trace-out FILE] [--metrics-out FILE] [--journal-out FILE] \
                     [--quiet]"
                );
                std::process::exit(0);
            }
            other => bail!("unknown argument {other:?}"),
        }
    }
    let spec = if quick {
        nephele::pipeline::multi::MultiSpec::quick()
    } else {
        nephele::pipeline::multi::MultiSpec::default()
    };
    let policies =
        policies.unwrap_or_else(|| vec![PlacementPolicy::Spread, PlacementPolicy::Pack]);
    let phases =
        phases.unwrap_or_else(|| nephele::experiments::multi::Phase::ALL.to_vec());
    Ok((spec, cfg, policies, tolerance, verbose, phases, tel))
}

/// Parse the load-surge driver's arguments (`argv` holds only the
/// flags, with the program/subcommand name already stripped):
/// `--secs N --seed N --scaling true|false --surge-at SECS --constraint-ms N --quiet`.
/// Returns `(spec, cfg, secs, scaling_enabled, verbose)`.
pub fn surge_args(
    argv: &[String],
    default_secs: u64,
) -> Result<(nephele::pipeline::surge::SurgeSpec, EngineConfig, u64, bool, bool)> {
    let mut spec = nephele::pipeline::surge::SurgeSpec::default();
    let mut scaling = true;
    let (cfg, secs, verbose) = scenario_args(
        argv,
        default_secs,
        "usage: [--secs N] [--seed N] [--scaling true|false] [--surge-at SECS] \
         [--constraint-ms N] [--quiet]",
        &["--scaling", "--surge-at", "--constraint-ms"],
        &mut |flag, value| {
            match flag {
                "--scaling" => scaling = value.parse()?,
                "--surge-at" => {
                    spec.surge_at = nephele::util::time::Duration::from_secs(value.parse()?)
                }
                "--constraint-ms" => spec.constraint_ms = value.parse()?,
                _ => unreachable!("unlisted scenario flag {flag}"),
            }
            Ok(())
        },
    )?;
    Ok((spec, cfg, secs, scaling, verbose))
}

/// Parse the failover driver's arguments (`argv` holds only the flags,
/// with the program/subcommand name already stripped):
/// `--secs N --seed N --recovery true|false --fail-at SECS --constraint-ms N
/// --trace-out FILE --metrics-out FILE --journal-out FILE --quiet`.
/// Returns `(spec, cfg, secs, recovery_enabled, verbose, tel)`.
pub fn failover_args(
    argv: &[String],
    default_secs: u64,
) -> Result<(
    nephele::pipeline::failover::FailoverSpec,
    EngineConfig,
    u64,
    bool,
    bool,
    TelemetryOut,
)> {
    let mut spec = nephele::pipeline::failover::FailoverSpec::default();
    let mut recovery = true;
    let mut tel = TelemetryOut::default();
    let (cfg, secs, verbose) = scenario_args(
        argv,
        default_secs,
        "usage: [--secs N] [--seed N] [--recovery true|false] [--fail-at SECS] \
         [--constraint-ms N] [--trace-out FILE] [--metrics-out FILE] \
         [--journal-out FILE] [--quiet]",
        &["--recovery", "--fail-at", "--constraint-ms", "--trace-out", "--metrics-out",
          "--journal-out"],
        &mut |flag, value| {
            if tel.accept(flag, value) {
                return Ok(());
            }
            match flag {
                "--recovery" => recovery = value.parse()?,
                "--fail-at" => {
                    spec.fail_at = nephele::util::time::Duration::from_secs(value.parse()?)
                }
                "--constraint-ms" => spec.constraint_ms = value.parse()?,
                _ => unreachable!("unlisted scenario flag {flag}"),
            }
            Ok(())
        },
    )?;
    Ok((spec, cfg, secs, recovery, verbose, tel))
}

/// Parse the paper-scale comparison driver's arguments (`argv` holds
/// only the flags, with the program/subcommand name already stripped):
/// `--quick --secs N --tail N --seed N --min-ratio F --quiet`.
/// Returns `(spec, cfg, secs, tail_secs, min_ratio, verbose, tel)`.
/// Defaults: 200 workers, 600 s with a 300 s measurement tail; `--quick`
/// drops to 20 workers, 420 s with a 180 s tail (same code path).
pub fn scale_args(
    argv: &[String],
) -> Result<(
    nephele::pipeline::scale::ScaleSpec,
    EngineConfig,
    u64,
    u64,
    f64,
    bool,
    TelemetryOut,
)> {
    let mut cfg = EngineConfig::default();
    let mut quick = false;
    let mut secs: Option<u64> = None;
    let mut tail: Option<u64> = None;
    let mut min_ratio = 13.0;
    let mut verbose = true;
    let mut tel = TelemetryOut::default();
    let mut i = 0;
    while i < argv.len() {
        let need = |i: usize| -> Result<&String> {
            argv.get(i + 1)
                .ok_or_else(|| anyhow::anyhow!("missing value after {}", argv[i]))
        };
        match argv[i].as_str() {
            "--quick" => {
                quick = true;
                i += 1;
            }
            "--secs" => {
                secs = Some(need(i)?.parse()?);
                i += 2;
            }
            "--tail" => {
                tail = Some(need(i)?.parse()?);
                i += 2;
            }
            "--seed" => {
                cfg.seed = need(i)?.parse()?;
                i += 2;
            }
            "--min-ratio" => {
                min_ratio = need(i)?.parse()?;
                i += 2;
            }
            "--quiet" => {
                verbose = false;
                i += 1;
            }
            flag @ ("--trace-out" | "--metrics-out" | "--journal-out") => {
                tel.accept(flag, need(i)?);
                i += 2;
            }
            "--help" | "-h" => {
                println!(
                    "usage: [--quick] [--secs N] [--tail N] [--seed N] [--min-ratio F] \
                     [--trace-out FILE] [--metrics-out FILE] [--journal-out FILE] [--quiet]"
                );
                std::process::exit(0);
            }
            other => bail!("unknown argument {other:?}"),
        }
    }
    let spec = if quick {
        nephele::pipeline::scale::ScaleSpec::quick()
    } else {
        nephele::pipeline::scale::ScaleSpec::default()
    };
    let secs = secs.unwrap_or(if quick { 420 } else { 600 });
    let tail = tail.unwrap_or(if quick { 180 } else { 300 });
    Ok((spec, cfg, secs, tail, min_ratio, verbose, tel))
}

/// Shared output of the multi-job scheduler driver.
pub fn print_multi_summary(report: &nephele::experiments::multi::MultiReport) {
    println!(
        "== multi-job scheduler — policy {} on {} workers ==",
        report.policy, report.workers
    );
    for o in &report.outcomes {
        println!("{}", nephele::experiments::multi::render_outcome(o));
        println!("      slots {}", o.slots);
    }
    println!("  events: {}", report.events);
}

/// Shared output of the resource-governance phases (`sim-multi`).
pub fn print_phase_summary(report: &nephele::experiments::multi::PhaseReport) {
    println!("== sim-multi phase: {} ==", report.name);
    for line in &report.lines {
        println!("{line}");
    }
}

/// Shared output of the paper-scale comparison driver.
pub fn print_scale_summary(report: &nephele::experiments::scale::ScaleReport) {
    println!("== paper-scale comparison — Nephele vs Hadoop Online ==");
    println!("{}", nephele::experiments::scale::render_summary(report));
}

/// Shared output of the failover drivers (`failover` binary and
/// `nephele sim-failover`).
pub fn print_failover_summary(report: &nephele::experiments::failover::FailoverReport) {
    println!("== worker failure — pinning-aware recovery ==");
    print!("{}", report.final_breakdown.render());
    println!("{}", nephele::experiments::failover::render_summary(report));
}

/// Shared output of the load-surge drivers (`surge` binary and
/// `nephele sim-surge`).
pub fn print_surge_summary(report: &nephele::experiments::load_surge::SurgeReport) {
    println!("== load surge — elastic task scaling ==");
    print!("{}", report.final_breakdown.render());
    println!("{}", nephele::experiments::load_surge::render_summary(report));
}

#[allow(dead_code)]
pub fn print_scenario_summary(r: &ScenarioReport) {
    println!("== {} ==", r.scenario.title());
    println!(
        "converged total workflow latency: {:.1} ms (seq min {} / max {} ms)",
        r.converged_total_ms(),
        r.final_breakdown
            .seq_min_ms
            .map_or("n/a".into(), |v| format!("{v:.1}")),
        r.final_breakdown
            .seq_max_ms
            .map_or("n/a".into(), |v| format!("{v:.1}")),
    );
    println!(
        "ground-truth e2e mean: {} ms | buffer updates: {} | chains: {} | unresolvable: {} | delivered: {} | events: {}",
        r.e2e_mean_ms.map_or("n/a".into(), |v| format!("{v:.1}")),
        r.buffer_updates,
        r.chains_established,
        r.unresolvable,
        r.items_delivered,
        r.events,
    );
}
