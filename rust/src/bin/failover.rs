//! Worker-failure scenario driver: failure injection, detection via
//! missed QoS reports, and pinning-aware recovery end to end.  Crashes a
//! worker mid-run and prints whether the constraint recovered.
//!
//! Usage: `failover [--secs N] [--seed N] [--recovery true|false]
//!                  [--fail-at SECS] [--constraint-ms N] [--quiet]`

#[path = "figbin_common.rs"]
mod figbin;

use nephele::experiments::failover::run_failover;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (spec, cfg, secs, recovery, verbose, tel) = figbin::failover_args(&argv, 600)?;
    let report = run_failover(spec, cfg, recovery, secs, verbose)?;
    figbin::print_failover_summary(&report);
    tel.write(&[("failover".to_string(), report.telemetry)])?;
    Ok(())
}
