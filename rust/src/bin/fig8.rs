//! Regenerates Fig. 8: latency with adaptive output buffer sizing
//! (§4.3.2).  Longer default horizon: the buffer convergence phase takes
//! several minutes of virtual time (the paper reports ~9 minutes).

#[path = "figbin_common.rs"]
mod figbin;

use nephele::experiments::video_scenarios::{run_video_scenario, Scenario};

fn main() -> anyhow::Result<()> {
    let (spec, cfg, secs, verbose) = figbin::video_args(std::env::args(), 900)?;
    let report = run_video_scenario(Scenario::AdaptiveBuffers, spec, cfg, secs, 30, verbose)?;
    figbin::print_scenario_summary(&report);
    Ok(())
}
