//! Engine/simulation configuration.
//!
//! Defaults model the paper's testbed (§4.2): commodity servers, Gigabit
//! Ethernet, NTP clock sync with <2 ms skew, 32 KB initial output
//! buffers, 15 s measurement interval.

use crate::graph::ids::WorkerId;
use crate::qos::manager::ManagerConfig;
use crate::util::time::Duration;

/// Cluster/platform model parameters.
#[derive(Debug, Clone, Copy)]
pub struct ClusterConfig {
    /// Outgoing link bandwidth per worker (bytes/s).  GbE = 125 MB/s.
    pub link_bytes_per_sec: f64,
    /// Fixed per-buffer transfer overhead (framing, syscalls, buffer meta
    /// data, memory management, thread synchronisation — §2.2.1).  This
    /// cost is serialised at the sender and is what collapses throughput
    /// for tiny buffers (Fig. 2b: flush mode caps at ~10 MBit/s).
    pub per_buffer_overhead: Duration,
    /// One-way software receive-path latency for remote channels
    /// (JVM/TCP stack, selector loops).  Calibrated against the paper's
    /// own Fig. 2 flush-mode baseline: 38 ms mean creation-to-arrival
    /// for single 128-byte items on an idle GbE link.
    pub base_latency: Duration,
    /// Same path for worker-local channels (TCP loopback; Nephele sends
    /// local channels through the network stack unless tasks are
    /// chained).
    pub local_latency: Duration,
    /// Rate at which a task thread serialises items into output buffers
    /// (memcpy-bound), bytes/s.
    pub serialize_bytes_per_sec: f64,
    /// Control-plane message delay (reports, actions).
    pub control_delay: Duration,
    /// Maximum absolute NTP clock offset per worker; tag-based channel
    /// latency measurements see the difference of two offsets (§4.2
    /// reports <2 ms skew).
    pub max_clock_skew: Duration,
    /// CPU cores per worker (Xeon E3-1230 V2: 4 cores / 8 threads).
    pub cores_per_worker: u32,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            link_bytes_per_sec: 125.0e6,
            per_buffer_overhead: Duration::from_micros(60),
            base_latency: Duration::from_millis(35),
            local_latency: Duration::from_millis(18),
            serialize_bytes_per_sec: 2.0e9,
            control_delay: Duration::from_micros(500),
            max_clock_skew: Duration::from_millis(1),
            cores_per_worker: 8,
        }
    }
}

/// One scheduled worker failure: at `at`, the worker's task threads,
/// NIC and in-flight buffers are dropped (fail-stop crash).  Handed to
/// [`crate::sim::cluster::SimCluster::schedule_failures`] by failure
/// scenarios.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FailureSpec {
    pub worker: WorkerId,
    pub at: Duration,
}

/// Master-side failure handling (the §3.6 motivation: pinning exists so
/// the engine can keep materialisation points for fault tolerance).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryConfig {
    /// Redeploy dead instances onto surviving workers and replay items
    /// buffered at `pin_unchainable` materialisation points.  When
    /// disabled, the master only unregisters the dead worker (detaching
    /// its instances from the routing tables) and accounts the lost
    /// items — the failure is detected but never repaired.
    pub enable_recovery: bool,
    /// Missed measurement intervals before a silent QoS Reporter's
    /// worker is declared failed (the detector adds half an interval of
    /// slack for report phase offsets and control-plane delay).
    pub detection_intervals: u32,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        RecoveryConfig { enable_recovery: true, detection_intervals: 2 }
    }
}

/// Streaming-engine parameters.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    pub cluster: ClusterConfig,
    /// Initial/default output buffer size (bytes); §4.2 uses 32 KB.
    pub default_buffer_size: u32,
    /// Measurement interval for reporters and managers; §4.2 uses 15 s.
    pub measurement_interval: Duration,
    pub manager: ManagerConfig,
    /// Worker-failure detection and recovery policy.
    pub recovery: RecoveryConfig,
    /// Deterministic seed for workloads, offsets, skew.
    pub seed: u64,
    /// Event-core shards (`--threads`): 1 keeps the serial oracle,
    /// N >= 2 partitions the event arena per worker group with merged,
    /// sequential-equivalent pops — same-seed trajectories are
    /// byte-identical across shard counts (enforced by the determinism
    /// suite; see `sim::shard` and DESIGN.md §10).
    pub threads: u32,
    /// Sample the deterministic metrics registry (gauges on scheduler
    /// ticks, CPU-utilisation gauges on CPU samples, per-job e2e latency
    /// histograms on sink delivery).  The typed trace journal is always
    /// on — only metrics sampling is gated, so the overhead of the whole
    /// observability layer can be measured (DESIGN.md §12).
    pub telemetry: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            cluster: ClusterConfig::default(),
            default_buffer_size: 32 * 1024,
            measurement_interval: Duration::from_secs(15),
            manager: ManagerConfig::default(),
            recovery: RecoveryConfig::default(),
            seed: 42,
            threads: 1,
            telemetry: true,
        }
    }
}

impl EngineConfig {
    /// The paper's scenario (1): constraints in place but optimisations
    /// disabled (§4.3.1).
    pub fn unoptimized(mut self) -> Self {
        self.manager.enable_buffer_sizing = false;
        self.manager.enable_chaining = false;
        self
    }

    /// Scenario (2): adaptive output buffer sizing only (§4.3.2).
    pub fn buffers_only(mut self) -> Self {
        self.manager.enable_buffer_sizing = true;
        self.manager.enable_chaining = false;
        self
    }

    /// Scenario (3): buffer sizing + dynamic task chaining (§4.3.3).
    pub fn fully_optimized(mut self) -> Self {
        self.manager.enable_buffer_sizing = true;
        self.manager.enable_chaining = true;
        self
    }

    /// Scenario extension: all three countermeasures, including elastic
    /// task scaling (the reproduction's addition on top of §4.3.3).
    pub fn with_scaling(mut self) -> Self {
        self.manager.enable_buffer_sizing = true;
        self.manager.enable_chaining = true;
        self.manager.enable_scaling = true;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_builders_toggle_flags() {
        let c = EngineConfig::default().unoptimized();
        assert!(!c.manager.enable_buffer_sizing && !c.manager.enable_chaining);
        let c = EngineConfig::default().buffers_only();
        assert!(c.manager.enable_buffer_sizing && !c.manager.enable_chaining);
        let c = EngineConfig::default().fully_optimized();
        assert!(c.manager.enable_buffer_sizing && c.manager.enable_chaining);
        assert!(!c.manager.enable_scaling, "scaling is opt-in");
        let c = EngineConfig::default().with_scaling();
        assert!(
            c.manager.enable_buffer_sizing
                && c.manager.enable_chaining
                && c.manager.enable_scaling
        );
    }

    #[test]
    fn recovery_defaults_are_armed_and_patient() {
        let c = EngineConfig::default();
        assert!(c.recovery.enable_recovery);
        assert_eq!(c.recovery.detection_intervals, 2);
        let f = FailureSpec { worker: WorkerId(2), at: Duration::from_secs(90) };
        assert_eq!(f, f);
    }

    #[test]
    fn defaults_match_paper_testbed() {
        let c = EngineConfig::default();
        assert_eq!(c.default_buffer_size, 32 * 1024);
        assert_eq!(c.measurement_interval, Duration::from_secs(15));
        assert_eq!(c.cluster.link_bytes_per_sec, 125.0e6);
    }
}
