#!/usr/bin/env python3
"""Validate a Chrome trace-event JSON file written by ``--trace-out``.

Usage: check_trace.py TRACE.json

The exporter (``src/telemetry/export.rs``) promises a deterministic,
Perfetto-loadable byte stream; this checker makes that promise a CI
gate instead of a claim.  It fails (exit 1) when:

  * the file is not valid JSON, or lacks the ``displayTimeUnit`` /
    ``traceEvents`` wrapper keys;
  * any event is missing the Chrome keys required for its phase
    (``name``/``ph``/``pid``/``tid`` everywhere, ``ts`` on instants and
    flows, ``s`` on instants, ``id`` on flows, ``args`` on metadata and
    instants);
  * instant-event (``ph:"i"``) timestamps are not monotone
    non-decreasing in array order per ``(pid, tid)`` track — the
    journal appends in sim-time order, so any inversion means the
    exporter reordered records;
  * an ``args.cause`` id does not resolve to an instant event emitted
    *earlier in the array* within the same process — cause links must
    point strictly backwards;
  * flow arrows are unpaired (a ``ph:"s"`` start without its ``ph:"f"``
    finish or vice versa), or a finish precedes its start in array
    order.

Stdlib only — no third-party dependencies.
"""

import json
import sys

REQUIRED_ALWAYS = ("name", "ph", "pid", "tid")


def check(trace):
    """Return a list of human-readable failure messages (empty = pass)."""
    failures = []

    for key in ("displayTimeUnit", "traceEvents"):
        if key not in trace:
            failures.append(f"wrapper key {key!r} missing")
    events = trace.get("traceEvents", [])
    if not isinstance(events, list) or not events:
        failures.append("traceEvents must be a non-empty array")
        return failures

    # (pid, tid) -> last instant ts seen, for monotonicity.
    last_ts = {}
    # pid -> set of trace ids whose instant event has already appeared.
    seen_traces = {}
    # flow id -> phases seen, in array order.
    flows = {}
    counts = {"M": 0, "i": 0, "s": 0, "f": 0}

    for i, e in enumerate(events):
        where = f"event[{i}]"
        missing = [k for k in REQUIRED_ALWAYS if k not in e]
        if missing:
            failures.append(f"{where}: missing keys {missing}")
            continue
        ph = e["ph"]
        counts[ph] = counts.get(ph, 0) + 1
        where = f"event[{i}] ({e['name']!r} ph={ph})"

        if ph == "M":
            if "args" not in e or "name" not in e.get("args", {}):
                failures.append(f"{where}: metadata needs args.name")
            continue

        if ph in ("i", "s", "f") and "ts" not in e:
            failures.append(f"{where}: missing ts")
            continue

        if ph == "i":
            if e.get("s") not in ("t", "p", "g"):
                failures.append(f"{where}: instant scope s={e.get('s')!r}")
            args = e.get("args")
            if not isinstance(args, dict) or "trace" not in args:
                failures.append(f"{where}: instant needs args.trace")
                continue
            track = (e["pid"], e["tid"])
            prev = last_ts.get(track)
            if prev is not None and e["ts"] < prev:
                failures.append(
                    f"{where}: ts {e['ts']} < {prev} on track pid={track[0]} "
                    f"tid={track[1]} (per-track timestamps must be monotone)"
                )
            last_ts[track] = e["ts"]
            seen = seen_traces.setdefault(e["pid"], set())
            cause = args.get("cause")
            if cause is not None and cause not in seen:
                failures.append(
                    f"{where}: args.cause {cause} does not resolve to an "
                    f"earlier instant in process {e['pid']}"
                )
            seen.add(args["trace"])
        elif ph in ("s", "f"):
            if "id" not in e:
                failures.append(f"{where}: flow needs id")
                continue
            flows.setdefault(e["id"], []).append(ph)
        else:
            failures.append(f"{where}: unexpected phase {ph!r}")

    for fid, phases in sorted(flows.items()):
        if phases != ["s", "f"]:
            failures.append(
                f"flow id {fid}: expected one start then one finish, saw {phases}"
            )

    if counts.get("i", 0) == 0:
        failures.append("no instant events: an empty trace is a masked failure")
    if counts.get("M", 0) == 0:
        failures.append("no process_name metadata events")

    print(
        f"traceEvents: {len(events)} "
        f"(metadata {counts.get('M', 0)}, instants {counts.get('i', 0)}, "
        f"flow starts {counts.get('s', 0)}, flow finishes {counts.get('f', 0)}, "
        f"tracks {len(last_ts)}, processes {len(seen_traces)})"
    )
    return failures


def main(path):
    try:
        with open(path) as f:
            trace = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL: {path}: {e}")
        return 1
    failures = check(trace)
    if failures:
        print(f"\nFAIL: {path}")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print(f"OK: {path} is a well-formed deterministic trace")
    return 0


# --- self-test fixtures --------------------------------------------------


def _instant(pid, tid, ts, trace_id, cause=None):
    args = {"trace": trace_id}
    if cause is not None:
        args["cause"] = cause
    return {
        "name": "worker-crash",
        "cat": "decision",
        "ph": "i",
        "s": "t",
        "pid": pid,
        "tid": tid,
        "ts": ts,
        "args": args,
    }


FIX_GOOD = {
    "displayTimeUnit": "ms",
    "traceEvents": [
        {"name": "process_name", "ph": "M", "pid": 0, "tid": 0, "args": {"name": "t"}},
        _instant(0, 3, 1000, 0),
        {"name": "cause", "cat": "cause", "ph": "s", "id": 7, "pid": 0, "tid": 3, "ts": 1000},
        _instant(0, 3, 2000, 1, cause=0),
        {"name": "cause", "cat": "cause", "ph": "f", "bp": "e", "id": 7, "pid": 0, "tid": 3, "ts": 2000},
    ],
}


def selftest():
    import copy

    checks = []
    checks.append(("well-formed trace passes", not check(copy.deepcopy(FIX_GOOD))))

    bad = copy.deepcopy(FIX_GOOD)
    bad["traceEvents"][3]["ts"] = 500
    checks.append(
        ("timestamp inversion fails", any("monotone" in m for m in check(bad)))
    )

    bad = copy.deepcopy(FIX_GOOD)
    bad["traceEvents"][3]["args"]["cause"] = 99
    checks.append(
        ("dangling cause fails", any("resolve" in m for m in check(bad)))
    )

    bad = copy.deepcopy(FIX_GOOD)
    del bad["traceEvents"][4]
    checks.append(("unpaired flow fails", any("flow id" in m for m in check(bad))))

    bad = copy.deepcopy(FIX_GOOD)
    del bad["displayTimeUnit"]
    checks.append(("missing wrapper key fails", any("wrapper" in m for m in check(bad))))

    print()
    nbad = 0
    for name, ok in checks:
        print(f"  {'ok' if ok else 'FAIL'}: {name}")
        nbad += 0 if ok else 1
    return 1 if nbad else 0


if __name__ == "__main__":
    if len(sys.argv) == 2 and sys.argv[1] == "--selftest":
        sys.exit(selftest())
    if len(sys.argv) != 2:
        print(__doc__)
        sys.exit(2)
    sys.exit(main(sys.argv[1]))
