#!/usr/bin/env python3
"""Diff a fresh hot-path bench run against the committed baseline.

Usage: bench_diff.py BASELINE.json CURRENT.json

Both files are Recorder JSON (``BENCH_hot_paths.json`` format).  Entries
are matched by name with digit runs normalised (``200000 sim-shaped
pops`` == ``2000000 sim-shaped pops``), so quick/full pop counts and
config-derived entry counts don't break the pairing.  The gate is
deliberately loose — CI runners vary a lot — and only fails when:

  * a matched events/sec entry drops below 30% of the baseline, or
  * the headline ``event_core_speedup`` falls below 2.0x (the ROADMAP
    perf target is >=3x; 2.0 leaves room for runner noise).

Everything else (faster runs, unmatched entries, missing throughput
numbers) is reported but non-fatal.  Stdlib only — no third-party
dependencies.
"""

import json
import re
import sys

REGRESSION_RATIO = 0.30
MIN_SPEEDUP = 2.0


def normalise(name):
    return re.sub(r"\d+", "N", name)


def by_name(report):
    out = {}
    for entry in report.get("results", []):
        out.setdefault(normalise(entry["name"]), entry)
    return out


def main(baseline_path, current_path):
    with open(baseline_path) as f:
        baseline = json.load(f)
    with open(current_path) as f:
        current = json.load(f)

    base_entries = by_name(baseline)
    cur_entries = by_name(current)
    failures = []

    print(f"baseline: {baseline_path} (quick={baseline.get('quick')})")
    print(f"current:  {current_path} (quick={current.get('quick')})")
    print()
    print(f"{'benchmark':<58} {'base ev/s':>12} {'cur ev/s':>12} {'ratio':>7}")
    for key in base_entries:
        base = base_entries[key]
        cur = cur_entries.get(key)
        if cur is None:
            print(f"{base['name']:<58} {'-':>12} {'(missing)':>12} {'-':>7}")
            continue
        beps, ceps = base.get("events_per_sec"), cur.get("events_per_sec")
        if not beps or not ceps:
            print(f"{base['name']:<58} {'-':>12} {'-':>12} {'-':>7}")
            continue
        ratio = ceps / beps
        flag = "  REGRESSION" if ratio < REGRESSION_RATIO else ""
        print(f"{cur['name']:<58} {beps:>12.3e} {ceps:>12.3e} {ratio:>6.2f}x{flag}")
        if ratio < REGRESSION_RATIO:
            failures.append(
                f"{cur['name']}: {ceps:.3e} ev/s is below "
                f"{REGRESSION_RATIO:.0%} of baseline {beps:.3e}"
            )
    for key in cur_entries:
        if key not in base_entries:
            print(f"{cur_entries[key]['name']:<58} {'(new)':>12}")

    base_speedup = baseline.get("event_core_speedup")
    cur_speedup = current.get("event_core_speedup")
    print()
    print(f"event_core_speedup: baseline {base_speedup}, current {cur_speedup}")
    if cur_speedup is not None and cur_speedup < MIN_SPEEDUP:
        failures.append(
            f"event_core_speedup {cur_speedup:.2f}x fell below the {MIN_SPEEDUP}x floor"
        )

    if failures:
        print("\nFAIL:")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("\nOK: no events/sec regression beyond the tolerance")
    return 0


if __name__ == "__main__":
    if len(sys.argv) != 3:
        print(__doc__)
        sys.exit(2)
    sys.exit(main(sys.argv[1], sys.argv[2]))
