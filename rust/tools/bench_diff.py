#!/usr/bin/env python3
"""Diff a fresh hot-path bench run against the committed baseline.

Usage: bench_diff.py BASELINE.json CURRENT.json
       bench_diff.py --refresh CURRENT.json BASELINE.json
       bench_diff.py --selftest

Both files are Recorder JSON (``BENCH_hot_paths.json`` format).  Entries
are matched by name with digit runs normalised (``200000 sim-shaped
pops`` == ``2000000 sim-shaped pops``), so quick/full pop counts and
config-derived entry counts don't break the pairing.  The gate is
deliberately loose — CI runners vary a lot — but it fails when:

  * a matched events/sec entry drops below 30% of the baseline,
  * a baseline entry is missing from the current run (a silently
    dropped benchmark is a masked regression, not a pass),
  * the headline ``event_core_speedup`` falls below 2.0x (the ROADMAP
    perf target is >=3x; 2.0 leaves room for runner noise),
  * ``sharded_core_speedup`` falls below 2.0x while the current run
    reports >= 4 cores (the full-bench target is >=4x on >=8 cores;
    2.0 is the quick/CI floor), or
  * ``telemetry_overhead_pct`` exceeds 5% (metrics sampling must stay
    effectively free on the hot simulation path; the bench takes the
    min of two runs per arm, so this headroom is for real overhead,
    not runner noise).

A baseline whose ``provenance`` is ``estimated`` (hand-written numbers,
never produced by a real run) is called out with a warning: refresh it
from a real run with ``--refresh CURRENT.json BASELINE.json``, which
validates the current report against the old baseline first and then
copies it over, stamping today's numbers as the new baseline.

Everything else (faster runs, new entries, missing throughput numbers)
is reported but non-fatal.  Stdlib only — no third-party dependencies.
``--selftest`` runs the embedded fixtures (unmatched-entry failure,
clean pass, regression failure) and exits non-zero on any mismatch.
"""

import json
import re
import sys

REGRESSION_RATIO = 0.30
MIN_SPEEDUP = 2.0
MIN_SHARDED_SPEEDUP = 2.0
SHARDED_GATE_MIN_CORES = 4
MAX_TELEMETRY_OVERHEAD_PCT = 5.0


def normalise(name):
    return re.sub(r"\d+", "N", name)


def by_name(report):
    out = {}
    for entry in report.get("results", []):
        out.setdefault(normalise(entry["name"]), entry)
    return out


def diff(baseline, current):
    """Compare two loaded Recorder reports.

    Returns (failures, warnings): lists of human-readable messages.
    Prints the comparison table as a side effect.
    """
    base_entries = by_name(baseline)
    cur_entries = by_name(current)
    failures = []
    warnings = []

    if baseline.get("provenance", "measured") == "estimated":
        warnings.append(
            "baseline provenance is 'estimated' (hand-written numbers): refresh it "
            "from a real run with --refresh CURRENT.json BASELINE.json"
        )

    print(f"{'benchmark':<58} {'base ev/s':>12} {'cur ev/s':>12} {'ratio':>7}")
    for key in base_entries:
        base = base_entries[key]
        cur = cur_entries.get(key)
        if cur is None:
            print(f"{base['name']:<58} {'-':>12} {'(MISSING)':>12} {'-':>7}")
            failures.append(
                f"{base['name']}: present in the baseline but missing from the "
                f"current run (dropped benchmarks mask regressions)"
            )
            continue
        beps, ceps = base.get("events_per_sec"), cur.get("events_per_sec")
        if not beps or not ceps:
            print(f"{base['name']:<58} {'-':>12} {'-':>12} {'-':>7}")
            continue
        ratio = ceps / beps
        flag = "  REGRESSION" if ratio < REGRESSION_RATIO else ""
        print(f"{cur['name']:<58} {beps:>12.3e} {ceps:>12.3e} {ratio:>6.2f}x{flag}")
        if ratio < REGRESSION_RATIO:
            failures.append(
                f"{cur['name']}: {ceps:.3e} ev/s is below "
                f"{REGRESSION_RATIO:.0%} of baseline {beps:.3e}"
            )
    for key in cur_entries:
        if key not in base_entries:
            print(f"{cur_entries[key]['name']:<58} {'(new)':>12}")

    base_speedup = baseline.get("event_core_speedup")
    cur_speedup = current.get("event_core_speedup")
    print()
    print(f"event_core_speedup: baseline {base_speedup}, current {cur_speedup}")
    if cur_speedup is not None and cur_speedup < MIN_SPEEDUP:
        failures.append(
            f"event_core_speedup {cur_speedup:.2f}x fell below the {MIN_SPEEDUP}x floor"
        )

    sharded = current.get("sharded_core_speedup")
    cores = current.get("cores")
    print(
        f"sharded_core_speedup: baseline {baseline.get('sharded_core_speedup')}, "
        f"current {sharded} (cores {cores})"
    )
    if sharded is not None and cores is not None and cores >= SHARDED_GATE_MIN_CORES:
        if sharded < MIN_SHARDED_SPEEDUP:
            failures.append(
                f"sharded_core_speedup {sharded:.2f}x fell below the "
                f"{MIN_SHARDED_SPEEDUP}x floor on {cores:.0f} cores"
            )

    overhead = current.get("telemetry_overhead_pct")
    print(
        f"telemetry_overhead_pct: baseline {baseline.get('telemetry_overhead_pct')}, "
        f"current {overhead}"
    )
    if overhead is not None and overhead > MAX_TELEMETRY_OVERHEAD_PCT:
        failures.append(
            f"telemetry_overhead_pct {overhead:.2f}% exceeds the "
            f"{MAX_TELEMETRY_OVERHEAD_PCT}% ceiling (metrics sampling must stay "
            f"effectively free)"
        )

    return failures, warnings


def load(path):
    with open(path) as f:
        return json.load(f)


def report(failures, warnings):
    for msg in warnings:
        print(f"\nWARNING: {msg}")
    if failures:
        print("\nFAIL:")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("\nOK: no events/sec regression beyond the tolerance")
    return 0


def main(baseline_path, current_path):
    baseline = load(baseline_path)
    current = load(current_path)
    print(f"baseline: {baseline_path} (quick={baseline.get('quick')})")
    print(f"current:  {current_path} (quick={current.get('quick')})")
    print()
    failures, warnings = diff(baseline, current)
    return report(failures, warnings)


def refresh(current_path, baseline_path):
    """Validate CURRENT against the old baseline, then install it as the
    new baseline.  Refuses to install a report that fails the diff gate
    or was not produced by a real run (provenance != "measured")."""
    baseline = load(baseline_path)
    current = load(current_path)
    print(f"refreshing baseline {baseline_path} from {current_path}")
    print()
    failures, _warnings = diff(baseline, current)
    if current.get("provenance") != "measured":
        failures.append(
            f"current report provenance is {current.get('provenance')!r}, "
            f"expected 'measured' — refresh only from a real bench run"
        )
    if failures:
        print("\nREFRESH REFUSED:")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    with open(baseline_path, "w") as f:
        json.dump(current, f, indent=2)
        f.write("\n")
    print(f"\nOK: {baseline_path} now holds the measured run from {current_path}")
    return 0


# --- self-test fixtures --------------------------------------------------

FIX_BASE = {
    "bench": "hot_paths",
    "quick": True,
    "provenance": "measured",
    "event_core_speedup": 3.4,
    "sharded_core_speedup": 2.5,
    "results": [
        {"name": "core: 200 pops", "iters": 1, "secs": 0.1, "events_per_sec": 2000.0},
        {"name": "sim: 90s virtual", "iters": 1, "secs": 1.0, "events_per_sec": 5000.0},
    ],
}


def _with(base, **kv):
    out = json.loads(json.dumps(base))
    out.update(kv)
    return out


def selftest():
    checks = []

    # 1. Identical reports pass.
    f, _ = diff(FIX_BASE, FIX_BASE)
    checks.append(("identical reports pass", not f))

    # 2. A baseline entry missing from the current run must FAIL — this
    # is the masked-bug regression: the old tool printed "(missing)" and
    # passed vacuously.
    cur = _with(FIX_BASE, results=[FIX_BASE["results"][0]])
    f, _ = diff(FIX_BASE, cur)
    checks.append(("unmatched baseline entry fails", any("missing" in m for m in f)))

    # 3. An events/sec collapse beyond the tolerance fails.
    cur = json.loads(json.dumps(FIX_BASE))
    cur["results"][1]["events_per_sec"] = 100.0
    f, _ = diff(FIX_BASE, cur)
    checks.append(("throughput regression fails", any("below" in m for m in f)))

    # 4. New current-only entries stay non-fatal.
    cur = json.loads(json.dumps(FIX_BASE))
    cur["results"].append(
        {"name": "new: 5 things", "iters": 1, "secs": 0.1, "events_per_sec": 10.0}
    )
    f, _ = diff(FIX_BASE, cur)
    checks.append(("new entries are non-fatal", not f))

    # 5. The sharded-core gate trips only when the runner has the cores.
    cur = _with(FIX_BASE, sharded_core_speedup=1.2, cores=8.0)
    f, _ = diff(FIX_BASE, cur)
    checks.append(("low sharded speedup on 8 cores fails", any("sharded" in m for m in f)))
    cur = _with(FIX_BASE, sharded_core_speedup=1.2, cores=2.0)
    f, _ = diff(FIX_BASE, cur)
    checks.append(("low sharded speedup on 2 cores passes", not f))

    # 6. An estimated baseline warns but does not fail.
    base = _with(FIX_BASE, provenance="estimated")
    f, w = diff(base, FIX_BASE)
    checks.append(("estimated baseline warns", not f and any("estimated" in m for m in w)))

    # 7. The telemetry-overhead gate: over the ceiling fails, under (or
    # negative, i.e. noise made the off arm slower) passes, absent stays
    # non-fatal for older reports.
    cur = _with(FIX_BASE, telemetry_overhead_pct=9.5)
    f, _ = diff(FIX_BASE, cur)
    checks.append(("telemetry overhead over 5% fails", any("telemetry" in m for m in f)))
    cur = _with(FIX_BASE, telemetry_overhead_pct=-1.3)
    f, _ = diff(FIX_BASE, cur)
    checks.append(("negative telemetry overhead passes", not f))
    f, _ = diff(FIX_BASE, FIX_BASE)
    checks.append(("absent telemetry overhead is non-fatal", not f))

    print()
    bad = 0
    for name, ok in checks:
        print(f"  {'ok' if ok else 'FAIL'}: {name}")
        bad += 0 if ok else 1
    return 1 if bad else 0


if __name__ == "__main__":
    if len(sys.argv) == 2 and sys.argv[1] == "--selftest":
        sys.exit(selftest())
    if len(sys.argv) == 4 and sys.argv[1] == "--refresh":
        sys.exit(refresh(sys.argv[2], sys.argv[3]))
    if len(sys.argv) != 3:
        print(__doc__)
        sys.exit(2)
    sys.exit(main(sys.argv[1], sys.argv[2]))
