#!/usr/bin/env python3
"""Validate a ``nephele lint --format json`` report.

Usage: check_lint.py REPORT.json [--expect-rule RULE ...]

The linter (``src/lint/``) promises a deterministic, machine-readable
report; this checker makes that promise a CI gate instead of a claim.
It fails (exit 1) when:

  * the file is not valid JSON, or lacks the ``findings`` /
    ``suggestions`` / ``files_scanned`` wrapper keys;
  * any finding is missing ``rule``/``file``/``line``/``message``, or
    names a rule id the linter does not define (a typo in a rule id
    would make CI grep-gates silently vacuous);
  * findings are not sorted by ``(file, line, rule, message)`` or
    contain exact duplicates — the report contract that makes two runs
    byte-comparable;
  * ``suggestions`` is not a list of non-empty strings, or
    ``files_scanned`` is not a positive integer.

With ``--expect-rule RULE`` (repeatable) it additionally fails unless
at least one finding carries that rule id.  CI uses this to invert the
seeded-bad fixture tree: the linter must not merely exit non-zero on
the fixtures, it must exit non-zero *for the planted reason*.

Stdlib only — no third-party dependencies.
"""

import json
import sys

KNOWN_RULES = (
    "DET-HASH-ITER",
    "DET-WALLCLOCK",
    "EVT-EXHAUSTIVE",
    "EVT-UNWRAP-RATCHET",
    "JOURNAL-COVERAGE",
    "LINT-SUPPRESS",
    "LINT-SUPPRESS-UNUSED",
    "LOCK-CYCLE",
    "PANIC-REACH",
    "SHARD-LOCK",
)

REQUIRED_KEYS = ("rule", "file", "line", "message")


def check(report, expect_rules=()):
    """Return a list of human-readable failure messages (empty = pass)."""
    failures = []

    for key in ("findings", "suggestions", "files_scanned"):
        if key not in report:
            failures.append(f"wrapper key {key!r} missing")
    findings = report.get("findings", [])
    if not isinstance(findings, list):
        failures.append("findings must be an array")
        return failures

    keys = []
    for i, f in enumerate(findings):
        where = f"finding[{i}]"
        if not isinstance(f, dict):
            failures.append(f"{where}: not an object")
            continue
        missing = [k for k in REQUIRED_KEYS if k not in f]
        if missing:
            failures.append(f"{where}: missing keys {missing}")
            continue
        where = f"finding[{i}] ({f['rule']} {f['file']}:{f['line']})"
        if f["rule"] not in KNOWN_RULES:
            failures.append(f"{where}: unknown rule id {f['rule']!r}")
        if not isinstance(f["file"], str) or not f["file"]:
            failures.append(f"{where}: file must be a non-empty string")
        if not isinstance(f["line"], int) or isinstance(f["line"], bool) or f["line"] < 0:
            failures.append(f"{where}: line must be a non-negative integer")
        if not isinstance(f["message"], str) or not f["message"]:
            failures.append(f"{where}: message must be a non-empty string")
        keys.append((f["file"], f["line"], f["rule"], f["message"]))

    if keys != sorted(keys):
        failures.append("findings are not sorted by (file, line, rule, message)")
    if len(keys) != len(set(keys)):
        failures.append("findings contain exact duplicates")

    suggestions = report.get("suggestions", [])
    if not isinstance(suggestions, list) or any(
        not isinstance(s, str) or not s for s in suggestions
    ):
        failures.append("suggestions must be an array of non-empty strings")

    scanned = report.get("files_scanned")
    if not isinstance(scanned, int) or isinstance(scanned, bool) or scanned <= 0:
        failures.append(f"files_scanned must be a positive integer, got {scanned!r}")

    present = {k[2] for k in keys}
    for rule in expect_rules:
        if rule not in present:
            failures.append(
                f"expected at least one {rule} finding, found none "
                f"(present: {sorted(present) or 'nothing'})"
            )

    print(
        f"findings: {len(findings)} across {len({k[0] for k in keys})} file(s), "
        f"suggestions: {len(suggestions)}, files_scanned: {scanned}"
    )
    return failures


def main(path, expect_rules):
    try:
        with open(path) as f:
            report = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL: {path}: {e}")
        return 1
    failures = check(report, expect_rules)
    if failures:
        print(f"\nFAIL: {path}")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print(f"OK: {path} is a well-formed lint report")
    return 0


# --- self-test fixtures --------------------------------------------------


FIX_GOOD = {
    "findings": [
        {
            "rule": "PANIC-REACH",
            "file": "src/sim/cluster.rs",
            "line": 8,
            "message": "root SimCluster::handle reaches 2 panic site(s), budget 1",
        },
        {
            "rule": "LOCK-CYCLE",
            "file": "src/sim/locks.rs",
            "line": 11,
            "message": "lock-order cycle: acct -> bank -> acct",
        },
    ],
    "suggestions": ["sim/improved.rs: unwrap 5 -> 1"],
    "files_scanned": 3,
}


def selftest():
    import copy

    checks = []
    checks.append(("well-formed report passes", not check(copy.deepcopy(FIX_GOOD))))

    bad = copy.deepcopy(FIX_GOOD)
    bad["findings"][0]["rule"] = "PANIC-REACHY"
    checks.append(("unknown rule id fails", any("unknown rule" in m for m in check(bad))))

    bad = copy.deepcopy(FIX_GOOD)
    bad["findings"].reverse()
    checks.append(("unsorted findings fail", any("not sorted" in m for m in check(bad))))

    bad = copy.deepcopy(FIX_GOOD)
    bad["findings"].append(copy.deepcopy(bad["findings"][1]))
    checks.append(("duplicate finding fails", any("duplicates" in m for m in check(bad))))

    bad = copy.deepcopy(FIX_GOOD)
    del bad["findings"][0]["line"]
    checks.append(("missing finding key fails", any("missing keys" in m for m in check(bad))))

    bad = copy.deepcopy(FIX_GOOD)
    del bad["files_scanned"]
    checks.append(("missing wrapper key fails", any("wrapper" in m for m in check(bad))))

    checks.append(
        (
            "absent expected rule fails",
            any(
                "expected at least one" in m
                for m in check(copy.deepcopy(FIX_GOOD), ("JOURNAL-COVERAGE",))
            ),
        )
    )
    checks.append(
        (
            "present expected rule passes",
            not check(copy.deepcopy(FIX_GOOD), ("LOCK-CYCLE", "PANIC-REACH")),
        )
    )

    print()
    nbad = 0
    for name, ok in checks:
        print(f"  {'ok' if ok else 'FAIL'}: {name}")
        nbad += 0 if ok else 1
    return 1 if nbad else 0


if __name__ == "__main__":
    if len(sys.argv) == 2 and sys.argv[1] == "--selftest":
        sys.exit(selftest())
    if len(sys.argv) < 2 or sys.argv[1].startswith("--"):
        print(__doc__)
        sys.exit(2)
    expect = []
    rest = sys.argv[2:]
    while rest:
        if rest[0] == "--expect-rule" and len(rest) >= 2:
            expect.append(rest[1])
            rest = rest[2:]
        else:
            print(__doc__)
            sys.exit(2)
    sys.exit(main(sys.argv[1], tuple(expect)))
