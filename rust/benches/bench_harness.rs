//! Minimal benchmark harness (criterion is unavailable in the offline
//! build): measures wall time over warm-up + timed iterations and prints
//! criterion-style `name ... time per iter` lines.

use std::time::Instant;

/// Measure `f` and print mean time per iteration.  Returns mean seconds.
pub fn bench<T>(name: &str, iters: u32, mut f: impl FnMut() -> T) -> f64 {
    // Warm-up.
    for _ in 0..iters.div_ceil(10).max(1) {
        std::hint::black_box(f());
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(f());
    }
    let total = t0.elapsed().as_secs_f64();
    let per = total / iters as f64;
    let (val, unit) = if per >= 1.0 {
        (per, "s")
    } else if per >= 1e-3 {
        (per * 1e3, "ms")
    } else if per >= 1e-6 {
        (per * 1e6, "us")
    } else {
        (per * 1e9, "ns")
    };
    println!("{name:<56} {val:>10.3} {unit}/iter   ({iters} iters)");
    per
}

/// Measure a single long-running experiment and print its duration plus
/// a caller-formatted headline metric.
pub fn bench_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let secs = t0.elapsed().as_secs_f64();
    println!("{name:<56} {secs:>10.3} s (single run)");
    (out, secs)
}
