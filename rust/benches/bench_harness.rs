//! Minimal benchmark harness (criterion is unavailable in the offline
//! build): measures wall time over warm-up + timed iterations, prints
//! criterion-style `name ... time per iter` lines, and records results
//! into a hand-rolled JSON report so the perf trajectory is persisted
//! (`BENCH_hot_paths.json`) instead of scrolling away.

#![allow(dead_code)]

use std::io::Write;
use std::time::Instant;

/// Measure `f` and print mean time per iteration.  Returns mean seconds.
pub fn bench<T>(name: &str, iters: u32, mut f: impl FnMut() -> T) -> f64 {
    // Warm-up.
    for _ in 0..iters.div_ceil(10).max(1) {
        std::hint::black_box(f());
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(f());
    }
    let total = t0.elapsed().as_secs_f64();
    let per = total / iters as f64;
    let (val, unit) = if per >= 1.0 {
        (per, "s")
    } else if per >= 1e-3 {
        (per * 1e3, "ms")
    } else if per >= 1e-6 {
        (per * 1e6, "us")
    } else {
        (per * 1e9, "ns")
    };
    println!("{name:<56} {val:>10.3} {unit}/iter   ({iters} iters)");
    per
}

/// Measure a single long-running experiment and print its duration plus
/// a caller-formatted headline metric.
pub fn bench_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let secs = t0.elapsed().as_secs_f64();
    println!("{name:<56} {secs:>10.3} s (single run)");
    (out, secs)
}

/// One recorded measurement.
pub struct BenchEntry {
    pub name: String,
    pub iters: u32,
    /// Wall seconds per iteration (total wall time for single runs).
    pub secs: f64,
    /// Throughput in events per second, when the benchmark counts events.
    pub events_per_sec: Option<f64>,
}

/// Collects results and writes them as JSON.
#[derive(Default)]
pub struct Recorder {
    pub entries: Vec<BenchEntry>,
    /// Named headline scalars (e.g. the event-core speedup factor).
    pub scalars: Vec<(String, f64)>,
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        // Exponential keeps sub-microsecond per-iteration times (the
        // buffer-sizing bench is ~1e-8 s) distinguishable in the
        // trajectory; "1.234567e-8" is a valid JSON number.
        format!("{v:.6e}")
    } else {
        "null".to_string()
    }
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn add(&mut self, name: &str, iters: u32, secs: f64, events_per_sec: Option<f64>) {
        self.entries.push(BenchEntry { name: name.to_string(), iters, secs, events_per_sec });
    }

    pub fn scalar(&mut self, name: &str, value: f64) {
        self.scalars.push((name.to_string(), value));
    }

    /// Serialise everything to `path` (no serde in the offline build —
    /// the format is flat enough to emit by hand).  `provenance` records
    /// how the numbers came to be: the bench always writes "measured";
    /// a hand-estimated committed baseline says "estimated" so the diff
    /// tool can warn until a real run replaces it (`--refresh`).
    pub fn write_json(
        &self,
        path: &str,
        bench_name: &str,
        quick: bool,
        provenance: &str,
    ) -> std::io::Result<()> {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(bench_name)));
        out.push_str(&format!("  \"quick\": {quick},\n"));
        out.push_str(&format!("  \"provenance\": \"{}\",\n", json_escape(provenance)));
        for (name, value) in &self.scalars {
            out.push_str(&format!("  \"{}\": {},\n", json_escape(name), json_f64(*value)));
        }
        out.push_str("  \"results\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            let eps = e
                .events_per_sec
                .map_or("null".to_string(), json_f64);
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"iters\": {}, \"secs\": {}, \"events_per_sec\": {}}}{}\n",
                json_escape(&e.name),
                e.iters,
                json_f64(e.secs),
                eps,
                if i + 1 == self.entries.len() { "" } else { "," }
            ));
        }
        out.push_str("  ]\n}\n");
        let mut f = std::fs::File::create(path)?;
        f.write_all(out.as_bytes())
    }
}
