//! Hot-path microbenchmarks (the §Perf targets in EXPERIMENTS.md):
//! event-core throughput (arena + time wheel vs the legacy binary
//! heap), the sharded parallel runner vs its 1-shard serial oracle,
//! the channel send/flush path, QoS setup at paper scale, manager
//! ingest/evaluate, and the buffer-sizing decision.
//!
//! Run with `cargo bench --bench hot_paths`.  Results are persisted to
//! `BENCH_hot_paths.json` (override with `NEPHELE_BENCH_OUT`); set
//! `NEPHELE_BENCH_QUICK=1` for the reduced CI smoke configuration.

#[path = "bench_harness.rs"]
mod harness;
use harness::{bench, bench_once, Recorder};

use nephele::actions::buffer_sizing::{next_buffer_size, BufferSizingConfig};
use nephele::config::EngineConfig;
use nephele::graph::ids::{ChannelId, JobId, VertexId, WorkerId};
use nephele::pipeline::microbench::{sender_receiver_job, MicrobenchSpec};
use nephele::pipeline::video::{video_job, VideoSpec};
use nephele::qos::manager::{ManagerConfig, QosManager};
use nephele::qos::sample::{ElementKey, MetricKind, Report, ReportEntry};
use nephele::qos::setup::compute_qos_setup;
use nephele::sim::cluster::SimCluster;
use nephele::sim::engine::EventCore;
use nephele::sim::events::EventQueue;
use nephele::util::rng::Rng;
use nephele::util::time::{Duration, Time};

/// A payload shaped like the simulator's `Ev` enum: the large variant
/// matches `Ev::Deliver`'s stack footprint, so the legacy heap pays the
/// same per-sift move cost it pays in the real event loop, while the
/// arena+wheel core sifts 24-byte keys.
#[derive(Clone)]
enum SimShapedEv {
    Deliver { payload: [u64; 11] },
    Tick { worker: u32 },
}

fn mk_ev(i: u64) -> SimShapedEv {
    if i % 4 == 0 {
        SimShapedEv::Tick { worker: (i % 200) as u32 }
    } else {
        SimShapedEv::Deliver { payload: [i; 11] }
    }
}

fn fold_ev(acc: u64, ev: &SimShapedEv) -> u64 {
    match ev {
        SimShapedEv::Deliver { payload } => acc ^ payload[0],
        SimShapedEv::Tick { worker } => acc ^ *worker as u64,
    }
}

/// The simulator's event mix in miniature: a standing population of
/// 10k pending events; each pop reschedules its event — mostly at
/// delivery/task-done horizons (0.1–50 ms), every 16th at the 15 s
/// measurement interval (the QoS report / liveness tick cadence).
macro_rules! drive_queue {
    ($queue:expr, $n_pops:expr) => {{
        let mut q = $queue;
        let mut rng = Rng::new(7);
        for i in 0..10_000u64 {
            q.push(Time(rng.below(1_000_000)), mk_ev(i));
        }
        let mut acc = 0u64;
        for i in 0..$n_pops {
            let (t, ev) = q.pop().expect("standing population never drains");
            acc = acc.wrapping_add(t.0) ^ fold_ev(acc, &ev);
            let dt = if i % 16 == 0 { 15_000_000 } else { 100 + rng.below(50_000) };
            q.push(Time(t.0 + dt), ev);
        }
        acc
    }};
}

/// The tentpole microbench: legacy heap vs arena+wheel on the identical
/// deterministic workload.  Records the speedup factor (target: >=3x).
fn bench_event_core(rec: &mut Recorder, quick: bool) {
    let n_pops: u64 = if quick { 200_000 } else { 2_000_000 };

    let name_old = format!("event core: legacy heap (EventQueue), {n_pops} sim-shaped pops");
    let (acc_old, secs_old) = bench_once(&name_old, || {
        drive_queue!(EventQueue::<SimShapedEv>::new(), n_pops)
    });
    rec.add(&name_old, 1, secs_old, Some(n_pops as f64 / secs_old));

    let name_new = format!("event core: arena + time wheel (EventCore), {n_pops} sim-shaped pops");
    let (acc_new, secs_new) = bench_once(&name_new, || {
        drive_queue!(EventCore::<SimShapedEv>::new(), n_pops)
    });
    rec.add(&name_new, 1, secs_new, Some(n_pops as f64 / secs_new));

    assert_eq!(
        acc_old, acc_new,
        "both queues must pop the identical event sequence"
    );
    let speedup = secs_old / secs_new;
    println!(
        "    -> {:.2} M pops/s vs {:.2} M pops/s = {speedup:.2}x speedup",
        n_pops as f64 / secs_old / 1e6,
        n_pops as f64 / secs_new / 1e6,
    );
    rec.scalar("event_core_speedup", speedup);
}

/// The sharded-core scenario: the identical self-contained stream
/// workload on the conservative parallel runner, once at 1 shard (the
/// serial-oracle arm) and once at one shard per core (capped at 8).
/// Event times are pure functions of the stream state, so both arms
/// must process the identical event multiset up to the virtual-time
/// horizon — count and order-independent digest are asserted equal —
/// and the recorded speedup therefore compares equal work.
fn bench_sharded_core(rec: &mut Recorder, quick: bool) {
    use nephele::sim::shard::ShardedEventCore;

    fn mix(mut x: u64) -> u64 {
        x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
        x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^ (x >> 31)
    }

    let streams: u64 = 1024;
    let virt_secs: u64 = if quick { 1 } else { 8 };
    let virt = Time(virt_secs * 1_000_000);
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get() as u32);
    let shards = cores.clamp(2, 8);

    let run = |n_shards: u32| -> (u64, u64) {
        let mut core: ShardedEventCore<u64> =
            ShardedEventCore::new(n_shards, Duration::from_millis(10));
        for s in 0..streams {
            core.push_to((s % n_shards as u64) as u32, Time(100 + s % 1_000), mix(s));
        }
        let mut states = vec![(0u64, 0u64); n_shards as usize];
        let report = core.run_parallel(virt, &mut states, |acc, _shard, t, ev, em| {
            acc.0 += 1;
            acc.1 ^= t.0.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ ev;
            let next = mix(ev ^ t.0);
            if next % 16 == 0 {
                // Cross-shard hop at >= one lookahead horizon (a remote
                // NIC transit); self-routes at one shard.
                let dest = ((next >> 32) % n_shards as u64) as u32;
                em.remote(dest, Time(t.0 + 10_000 + next % 5_000), next);
            } else {
                em.local(Time(t.0 + 100 + next % 1_800), next);
            }
        });
        let count: u64 = states.iter().map(|s| s.0).sum();
        assert_eq!(count, report.events, "runner event count disagrees with the states");
        (count, states.iter().fold(0u64, |a, s| a ^ s.1))
    };

    let name_serial = format!(
        "event core: sharded runner, 1 shard (serial oracle), {streams} streams, \
         {virt_secs}s virtual"
    );
    let ((count_1, digest_1), secs_1) = bench_once(&name_serial, || run(1));
    rec.add(&name_serial, 1, secs_1, Some(count_1 as f64 / secs_1));

    let name_sharded = format!(
        "event core: sharded runner, {shards} shards, {streams} streams, {virt_secs}s virtual"
    );
    let ((count_s, digest_s), secs_s) = bench_once(&name_sharded, || run(shards));
    rec.add(&name_sharded, 1, secs_s, Some(count_s as f64 / secs_s));

    assert_eq!(
        (count_1, digest_1),
        (count_s, digest_s),
        "both arms must process the identical event multiset"
    );
    let speedup = secs_1 / secs_s;
    println!(
        "    -> {:.2} M ev/s serial vs {:.2} M ev/s on {shards} shards = {speedup:.2}x \
         ({cores} cores)",
        count_1 as f64 / secs_1 / 1e6,
        count_s as f64 / secs_s / 1e6,
    );
    rec.scalar("sharded_core_speedup", speedup);
    rec.scalar("cores", cores as f64);
    if quick && cores >= 4 {
        assert!(speedup >= 2.0, "sharded core below 2x on {cores} cores: {speedup:.2}x");
    }
    if !quick && cores >= 8 {
        assert!(speedup >= 4.0, "sharded core below 4x on {cores} cores: {speedup:.2}x");
    }
}

fn bench_event_queue(rec: &mut Recorder) {
    // Push/pop throughput of the legacy structure on trivial payloads
    // (kept for trend comparison with older recordings).
    let n = 100_000u64;
    let secs = bench("event_queue: push+pop 100k interleaved", 20, || {
        let mut q: EventQueue<u64> = EventQueue::new();
        for i in 0..n {
            q.push(Time(i * 7919 % 1_000_000), i);
        }
        let mut acc = 0u64;
        while let Some((_, v)) = q.pop() {
            acc = acc.wrapping_add(v);
        }
        acc
    });
    rec.add("event_queue: push+pop 100k interleaved", 20, secs, Some(n as f64 / secs));
}

fn bench_channel_hot_path(rec: &mut Recorder, quick: bool) {
    // End-to-end simulator events/second on the 2-task microbenchmark:
    // this is the per-item channel path (emit -> buffer -> flush ->
    // deliver -> process).
    let (job, rg, constraints, specs, sources) =
        sender_receiver_job(MicrobenchSpec { items_per_sec: 100_000.0, ..Default::default() })
            .unwrap();
    let cfg = EngineConfig::default().unoptimized();
    let virt_secs = if quick { 5 } else { 30 };
    let name = format!("sim: microbench {virt_secs}s virtual @100k items/s");
    let (events, secs) = bench_once(&name, || {
        let mut cluster = SimCluster::new(
            job.clone(),
            rg.clone(),
            &constraints,
            specs.clone(),
            sources.clone(),
            cfg,
        )
        .unwrap();
        cluster.run(Duration::from_secs(virt_secs), None).unwrap();
        cluster.stats.events_processed
    });
    println!(
        "    -> {} events, {:.2} M events/s wall",
        events,
        events as f64 / secs / 1e6
    );
    rec.add(&name, 1, secs, Some(events as f64 / secs));
}

fn bench_video_sim_rate(rec: &mut Recorder, quick: bool) {
    // Whole-cluster simulation rate on the small video job.
    let vj = video_job(VideoSpec::small()).unwrap();
    let cfg = EngineConfig::default().fully_optimized();
    let virt_secs = if quick { 60 } else { 300 };
    let name = format!("sim: small video job, {virt_secs}s virtual, full QoS");
    let (events, secs) = bench_once(&name, || {
        let mut cluster = SimCluster::new(
            vj.job.clone(),
            vj.rg.clone(),
            &vj.constraints,
            vj.task_specs.clone(),
            vj.sources.clone(),
            cfg,
        )
        .unwrap();
        cluster.run(Duration::from_secs(virt_secs), None).unwrap();
        cluster.stats.events_processed
    });
    println!("    -> {} events processed", events);
    rec.add(&name, 1, secs, Some(events as f64 / secs));
}

/// The telemetry on/off pair: the full-QoS video sim with metrics
/// sampling enabled (the default) and disabled.  The journal is always
/// on — the action log derives from it — so this isolates exactly what
/// `EngineConfig::telemetry = false` turns off.  Min-of-two runs per
/// arm damps runner noise; the recorded `telemetry_overhead_pct`
/// scalar is gated at 5% in tools/bench_diff.py.
fn bench_telemetry_overhead(rec: &mut Recorder, quick: bool) {
    let vj = video_job(VideoSpec::small()).unwrap();
    let virt_secs: u64 = if quick { 60 } else { 180 };
    let mut measure = |telemetry: bool| -> (u64, f64) {
        let mut cfg = EngineConfig::default().fully_optimized();
        cfg.telemetry = telemetry;
        let mut best = f64::INFINITY;
        let mut events = 0u64;
        for _ in 0..2 {
            let mut cluster = SimCluster::new(
                vj.job.clone(),
                vj.rg.clone(),
                &vj.constraints,
                vj.task_specs.clone(),
                vj.sources.clone(),
                cfg,
            )
            .unwrap();
            let t0 = std::time::Instant::now();
            cluster.run(Duration::from_secs(virt_secs), None).unwrap();
            best = best.min(t0.elapsed().as_secs_f64());
            events = cluster.stats.events_processed;
        }
        (events, best)
    };
    let (ev_on, secs_on) = measure(true);
    let (ev_off, secs_off) = measure(false);
    assert_eq!(
        ev_on, ev_off,
        "metrics sampling must never perturb the event trajectory"
    );
    let name_on = format!("sim: small video job telemetry on, {virt_secs}s virtual");
    println!("{name_on:<56} {secs_on:>10.3} s (min of 2)");
    rec.add(&name_on, 1, secs_on, Some(ev_on as f64 / secs_on));
    let name_off = format!("sim: small video job telemetry off, {virt_secs}s virtual");
    println!("{name_off:<56} {secs_off:>10.3} s (min of 2)");
    rec.add(&name_off, 1, secs_off, Some(ev_off as f64 / secs_off));
    let pct = (secs_on / secs_off - 1.0) * 100.0;
    println!("    -> telemetry overhead {pct:+.2}% ({secs_on:.3}s on vs {secs_off:.3}s off)");
    rec.scalar("telemetry_overhead_pct", pct);
}

fn bench_qos_setup(rec: &mut Recorder, quick: bool) {
    // Algorithm 1-3 at the paper's full scale (512e6 runtime constraints);
    // the quick configuration uses the laptop-scale job.
    let (spec, iters) = if quick { (VideoSpec::small(), 2) } else { (VideoSpec::default(), 5) };
    let vj = video_job(spec).unwrap();
    let name = format!(
        "qos setup: ComputeQoSSetup m={} n={}",
        spec.parallelism, spec.workers
    );
    let secs = bench(&name, iters, || {
        compute_qos_setup(&vj.job, &vj.rg, &vj.constraints).unwrap().managers.len()
    });
    rec.add(&name, iters, secs, None);
}

fn bench_manager(rec: &mut Recorder, quick: bool) {
    // Manager ingest + evaluate on a paper-scale subgraph (800-channel
    // fan-in layers); laptop-scale in the quick configuration.
    let spec = if quick { VideoSpec::small() } else { VideoSpec::default() };
    let vj = video_job(spec).unwrap();
    let setup = compute_qos_setup(&vj.job, &vj.rg, &vj.constraints).unwrap();
    let (&w, sub) = setup.managers.iter().next().unwrap();
    let mut mgr = QosManager::new(w, sub.clone(), 32 * 1024, ManagerConfig::default());

    // One report covering every element of the subgraph.
    let mut entries = Vec::new();
    for chain in &sub.chains {
        for v in chain.vertices() {
            entries.push(ReportEntry {
                element: ElementKey::Vertex(v.id),
                kind: MetricKind::TaskLatency,
                mean: 1000.0,
                count: 1,
            });
        }
        for c in chain.channels() {
            entries.push(ReportEntry {
                element: ElementKey::Channel(c.id),
                kind: MetricKind::ChannelLatency,
                mean: 2000.0,
                count: 1,
            });
        }
    }
    let n_entries = entries.len();
    let report = Report {
        job: JobId(0),
        from: WorkerId(0),
        to_manager: w,
        at: Time::from_secs_f64(1.0),
        entries,
        buffer_updates: Vec::new(),
    };
    let name_ingest = format!("manager: ingest report with {n_entries} entries");
    let secs = bench(&name_ingest, 50, || mgr.ingest(&report));
    rec.add(&name_ingest, 50, secs, None);
    let name_eval = format!("manager: evaluate chains (m={})", spec.parallelism);
    let secs = bench(&name_eval, 50, || {
        mgr.evaluate_chains(Time::from_secs_f64(1.0)).len()
    });
    rec.add(&name_eval, 50, secs, None);
}

fn bench_multi_sim_rate(rec: &mut Recorder, quick: bool) {
    // Scheduler-path events/second: the multi-job cluster with staggered
    // submissions, per-job QoS runtimes and completion watches — the
    // `nephele sim-multi` code path.
    use nephele::pipeline::multi::{latency_submission, throughput_submission, MultiSpec};
    use nephele::sched::PlacementPolicy;

    let spec = if quick { MultiSpec::tiny() } else { MultiSpec::quick() };
    let virt_secs = if quick { 90 } else { 240 };
    let name = format!(
        "sim: multi-job scheduler ({} jobs, {} workers), {virt_secs}s virtual",
        spec.latency_jobs + 1,
        spec.workers
    );
    let (events, secs) = bench_once(&name, || {
        let mut cluster = SimCluster::new_multi(
            spec.workers,
            spec.slots_per_worker,
            PlacementPolicy::Spread,
            EngineConfig::default().fully_optimized(),
        )
        .unwrap();
        cluster
            .submit_job(throughput_submission(&spec).unwrap(), Duration::ZERO)
            .unwrap();
        for i in 0..spec.latency_jobs {
            cluster
                .submit_job(latency_submission(&spec, i).unwrap(), spec.latency_submit_at(i))
                .unwrap();
        }
        cluster.run(Duration::from_secs(virt_secs), None).unwrap();
        cluster.stats.events_processed
    });
    println!("    -> {} events, {:.2} M events/s wall", events, events as f64 / secs / 1e6);
    rec.add(&name, 1, secs, Some(events as f64 / secs));
}

fn bench_admission_path(rec: &mut Recorder, quick: bool) {
    // Admission-path events/second: a stream of bounded submissions
    // churning through queue -> admit -> complete on a pool that holds
    // only two at a time, so every scheduler tick re-runs admission and
    // samples occupancy.  Tracks the scheduler-tick overhead the
    // resource-governance layer adds.
    use nephele::pipeline::multi::holder_submission;
    use nephele::sched::PlacementPolicy;

    let n_jobs: u64 = if quick { 6 } else { 12 };
    let virt_secs = if quick { 120 } else { 220 };
    let name = format!(
        "sim: admission/queue churn ({n_jobs} staggered jobs, 4x4 pool), {virt_secs}s virtual"
    );
    let (events, secs) = bench_once(&name, || {
        let mut cluster = SimCluster::new_multi(
            4,
            4,
            PlacementPolicy::Spread,
            EngineConfig::default().fully_optimized(),
        )
        .unwrap();
        for i in 0..n_jobs {
            cluster
                .submit_job(
                    holder_submission(&format!("churn-{i}"), Duration::from_secs(25)).unwrap(),
                    Duration::from_secs(10 * i),
                )
                .unwrap();
        }
        cluster.run(Duration::from_secs(virt_secs), None).unwrap();
        assert!(cluster.stats.jobs_queued > 0, "the churn must exercise the queue");
        cluster.stats.events_processed
    });
    println!("    -> {} events, {:.2} M events/s wall", events, events as f64 / secs / 1e6);
    rec.add(&name, 1, secs, Some(events as f64 / secs));
}

fn bench_buffer_sizing(rec: &mut Recorder) {
    let cfg = BufferSizingConfig::default();
    let name = "buffer sizing: Eq.2/3 decision";
    let secs = bench(name, 1_000_000, || {
        next_buffer_size(32 * 1024, 42.0, Some(3.0), &cfg)
    });
    rec.add(name, 1_000_000, secs, None);
    // Referenced ids to keep imports honest.
    let _ = (ChannelId(0), VertexId(0));
}

fn main() {
    // Presence alone is not opt-in: NEPHELE_BENCH_QUICK=0 (or empty)
    // must still run the full configuration.
    let quick = std::env::var("NEPHELE_BENCH_QUICK")
        .map_or(false, |v| !v.is_empty() && v != "0");
    let out_path = std::env::var("NEPHELE_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_hot_paths.json".to_string());
    println!(
        "== hot-path benchmarks{} ==",
        if quick { " (quick)" } else { "" }
    );
    let mut rec = Recorder::new();
    bench_event_core(&mut rec, quick);
    bench_sharded_core(&mut rec, quick);
    bench_event_queue(&mut rec);
    bench_buffer_sizing(&mut rec);
    bench_qos_setup(&mut rec, quick);
    bench_manager(&mut rec, quick);
    bench_channel_hot_path(&mut rec, quick);
    bench_video_sim_rate(&mut rec, quick);
    bench_telemetry_overhead(&mut rec, quick);
    bench_multi_sim_rate(&mut rec, quick);
    bench_admission_path(&mut rec, quick);
    match rec.write_json(&out_path, "hot_paths", quick, "measured") {
        Ok(()) => println!("results written to {out_path}"),
        Err(e) => {
            eprintln!("failed to write {out_path}: {e}");
            std::process::exit(1);
        }
    }
}
