//! Hot-path microbenchmarks (the §Perf targets in EXPERIMENTS.md):
//! event-queue throughput, the channel send/flush path, QoS setup at
//! paper scale, manager ingest/evaluate, and the buffer-sizing decision.
//!
//! Run with `cargo bench --bench hot_paths`.

#[path = "bench_harness.rs"]
mod harness;
use harness::{bench, bench_once};

use nephele::actions::buffer_sizing::{next_buffer_size, BufferSizingConfig};
use nephele::config::EngineConfig;
use nephele::graph::ids::{ChannelId, VertexId, WorkerId};
use nephele::pipeline::microbench::{sender_receiver_job, MicrobenchSpec};
use nephele::pipeline::video::{video_job, VideoSpec};
use nephele::qos::manager::{ManagerConfig, QosManager};
use nephele::qos::sample::{ElementKey, MetricKind, Report, ReportEntry};
use nephele::qos::setup::compute_qos_setup;
use nephele::sim::cluster::SimCluster;
use nephele::sim::events::EventQueue;
use nephele::util::time::{Duration, Time};

fn bench_event_queue() {
    // Push/pop throughput of the simulator's core data structure.
    let n = 100_000u64;
    bench("event_queue: push+pop 100k interleaved", 20, || {
        let mut q: EventQueue<u64> = EventQueue::new();
        for i in 0..n {
            q.push(Time(i * 7919 % 1_000_000), i);
        }
        let mut acc = 0u64;
        while let Some((_, v)) = q.pop() {
            acc = acc.wrapping_add(v);
        }
        acc
    });
}

fn bench_channel_hot_path() {
    // End-to-end simulator events/second on the 2-task microbenchmark:
    // this is the per-item channel path (emit -> buffer -> flush ->
    // deliver -> process).
    let (job, rg, constraints, specs, sources) =
        sender_receiver_job(MicrobenchSpec { items_per_sec: 100_000.0, ..Default::default() })
            .unwrap();
    let cfg = EngineConfig::default().unoptimized();
    let ((), secs) = bench_once("sim: microbench 30s virtual @100k items/s", || {
        let mut cluster = SimCluster::new(
            job.clone(),
            rg.clone(),
            &constraints,
            specs.clone(),
            sources.clone(),
            cfg,
        )
        .unwrap();
        cluster.run(Duration::from_secs(30), None);
        let ev = cluster.stats.events_processed;
        println!(
            "    -> {} events, {:.2} M events/s wall",
            ev,
            ev as f64 / 1e6
        );
    });
    let _ = secs;
}

fn bench_video_sim_rate() {
    // Whole-cluster simulation rate on the small video job.
    let vj = video_job(VideoSpec::small()).unwrap();
    let cfg = EngineConfig::default().fully_optimized();
    bench_once("sim: small video job, 300s virtual, full QoS", || {
        let mut cluster = SimCluster::new(
            vj.job.clone(),
            vj.rg.clone(),
            &vj.constraints,
            vj.task_specs.clone(),
            vj.sources.clone(),
            cfg,
        )
        .unwrap();
        cluster.run(Duration::from_secs(300), None);
        println!(
            "    -> {} events processed",
            cluster.stats.events_processed
        );
    });
}

fn bench_qos_setup() {
    // Algorithm 1-3 at the paper's full scale (512e6 runtime constraints).
    let vj = video_job(VideoSpec::default()).unwrap();
    bench("qos setup: ComputeQoSSetup m=800 n=200 (512e6 seqs)", 5, || {
        compute_qos_setup(&vj.job, &vj.rg, &vj.constraints).unwrap().managers.len()
    });
}

fn bench_manager() {
    // Manager ingest + evaluate on a paper-scale subgraph (800-channel
    // fan-in layers).
    let vj = video_job(VideoSpec::default()).unwrap();
    let setup = compute_qos_setup(&vj.job, &vj.rg, &vj.constraints).unwrap();
    let (&w, sub) = setup.managers.iter().next().unwrap();
    let mut mgr = QosManager::new(w, sub.clone(), 32 * 1024, ManagerConfig::default());

    // One report covering every element of the subgraph.
    let mut entries = Vec::new();
    for chain in &sub.chains {
        for v in chain.vertices() {
            entries.push(ReportEntry {
                element: ElementKey::Vertex(v.id),
                kind: MetricKind::TaskLatency,
                mean: 1000.0,
                count: 1,
            });
        }
        for c in chain.channels() {
            entries.push(ReportEntry {
                element: ElementKey::Channel(c.id),
                kind: MetricKind::ChannelLatency,
                mean: 2000.0,
                count: 1,
            });
        }
    }
    let n_entries = entries.len();
    let report = Report {
        from: WorkerId(0),
        to_manager: w,
        at: Time::from_secs_f64(1.0),
        entries,
        buffer_updates: Vec::new(),
    };
    bench(
        &format!("manager: ingest report with {n_entries} entries"),
        50,
        || mgr.ingest(&report),
    );
    bench("manager: evaluate 4 chains (1600-wide layers)", 50, || {
        mgr.evaluate_chains(Time::from_secs_f64(1.0)).len()
    });
}

fn bench_buffer_sizing() {
    let cfg = BufferSizingConfig::default();
    bench("buffer sizing: Eq.2/3 decision", 1_000_000, || {
        next_buffer_size(32 * 1024, 42.0, Some(3.0), &cfg)
    });
    // Referenced ids to keep imports honest.
    let _ = (ChannelId(0), VertexId(0));
}

fn main() {
    println!("== hot-path benchmarks ==");
    bench_event_queue();
    bench_buffer_sizing();
    bench_qos_setup();
    bench_manager();
    bench_channel_hot_path();
    bench_video_sim_rate();
}
