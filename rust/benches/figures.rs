//! Per-figure benchmark harness: regenerates a scaled-down version of
//! every table and figure of the paper's evaluation in one `cargo bench`
//! run, printing the headline series.  The full-scale runs live in the
//! `fig2`/`fig7`..`fig10` binaries (see EXPERIMENTS.md).

#[path = "bench_harness.rs"]
mod harness;
use harness::bench_once;

use nephele::baseline::hadoop::HadoopSpec;
use nephele::config::EngineConfig;
use nephele::experiments::fig2::fig2_cell;
use nephele::experiments::hadoop::run_hadoop_online;
use nephele::experiments::video_scenarios::{run_video_scenario, Scenario};
use nephele::pipeline::video::VideoSpec;

fn fig2_mini() {
    println!("\n-- Fig. 2 (mini sweep): latency/throughput vs buffer size --");
    for (rate, secs) in [(100.0, 400), (100_000.0, 10)] {
        for buffer in [None, Some(4 * 1024), Some(64 * 1024)] {
            let cell = fig2_cell(rate, buffer, secs, 42).unwrap();
            println!(
                "  rate {:>7}/s buffer {:>6}: {:>10.1} ms, {:>8.2} MBit/s",
                rate,
                buffer.map_or("flush".into(), |b| format!("{}K", b / 1024)),
                cell.mean_latency_ms,
                cell.throughput_mbit
            );
        }
    }
}

fn figs_789_mini() {
    println!("\n-- Figs. 7/8/9 (small scale): the three scenarios --");
    let mut results = Vec::new();
    for (scenario, constraint) in [
        (Scenario::Unoptimized, 300),
        (Scenario::AdaptiveBuffers, 300),
        (Scenario::BuffersAndChaining, 107),
    ] {
        let mut spec = VideoSpec::small();
        spec.constraint_ms = constraint;
        let (report, _) = bench_once(&format!("scenario: {:?}", scenario), || {
            run_video_scenario(scenario, spec, EngineConfig::default(), 600, 600, false)
                .unwrap()
        });
        println!(
            "    -> total {:.1} ms (chains {}, buffer updates {})",
            report.converged_total_ms(),
            report.chains_established,
            report.buffer_updates
        );
        results.push(report.converged_total_ms());
    }
    println!(
        "  improvement unopt -> full: {:.1}x (paper >= 13x)",
        results[0] / results[2]
    );
}

fn fig10_mini() {
    println!("\n-- Fig. 10: Hadoop Online baseline --");
    let (report, _) = bench_once("hadoop online: 300s virtual", || {
        run_hadoop_online(HadoopSpec::default(), 300, 42).unwrap()
    });
    println!(
        "    -> total {:.1} ms over {} delivered items",
        report.breakdown.total_ms(),
        report.items_delivered
    );
}

fn ablation_buffer_sizing() {
    // Ablation of the §3.5.1 parameters DESIGN.md calls out: shrink base
    // r and floor ε.  Converged buffers-only latency on the small job.
    println!("\n-- Ablation: adaptive buffer sizing parameters --");
    for (r, eps) in [(0.90, 200u32), (0.98, 200), (0.995, 200), (0.98, 2048)] {
        let mut cfg = EngineConfig::default().buffers_only();
        cfg.manager.buffer.r = r;
        cfg.manager.buffer.min_size = eps;
        let report = run_video_scenario(
            Scenario::AdaptiveBuffers,
            VideoSpec::small(),
            cfg,
            600,
            600,
            false,
        )
        .unwrap();
        println!(
            "  r={r:<6} eps={eps:>5} B: converged {:>8.1} ms ({} updates)",
            report.converged_total_ms(),
            report.buffer_updates
        );
    }
    // Paper defaults (r=0.98, eps=200) should be on the efficient
    // frontier: aggressive r overshoots less but converges slower; a
    // large eps floors the achievable latency.
}

fn main() {
    println!("== figure regeneration benchmarks ==");
    fig2_mini();
    figs_789_mini();
    fig10_mini();
    ablation_buffer_sizing();
}
