//! Minimal offline stand-in for the `anyhow` crate, covering the API
//! surface this workspace uses: [`Result`], [`Error`], the `anyhow!` and
//! `bail!` macros, and the [`Context`] extension trait on both `Result`
//! and `Option`.
//!
//! The build environment resolves crates offline (no registry access), so
//! the real crates.io `anyhow` cannot be fetched; this path dependency
//! keeps `cargo build` hermetic.  Error values are plain messages —
//! backtraces and source chains are intentionally out of scope.

use std::fmt;

/// A message-carrying error type, convertible from any `std::error::Error`.
///
/// Like the real `anyhow::Error`, this type deliberately does **not**
/// implement `std::error::Error` itself: that keeps the blanket
/// `From<E: std::error::Error>` impl coherent with the reflexive
/// `From<Error> for Error` the `?` operator needs.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` with a defaulted error type, mirroring `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (or a single displayable
/// expression).
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $arg:expr)* $(,)?) => {
        $crate::Error::msg(format!($fmt $(, $arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Attach context to a `Result` or `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error (or `None`) with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parses(s: &str) -> Result<u32> {
        let n: u32 = s.parse()?; // From<ParseIntError> via the blanket impl
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parses("42").unwrap(), 42);
        assert!(parses("x").is_err());
    }

    #[test]
    fn macros_and_context() {
        let e = anyhow!("bad {} of {}", 1, 2);
        assert_eq!(e.to_string(), "bad 1 of 2");

        fn fails() -> Result<()> {
            bail!("nope {}", 7);
        }
        assert_eq!(fails().unwrap_err().to_string(), "nope 7");

        let r: std::result::Result<(), String> = Err("inner".into());
        assert_eq!(r.context("outer").unwrap_err().to_string(), "outer: inner");
        let o: Option<u8> = None;
        assert_eq!(o.with_context(|| "missing").unwrap_err().to_string(), "missing");
    }
}
