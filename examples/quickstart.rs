//! Quickstart: build the paper's video job at laptop scale, run the
//! three §4.3 scenarios on the simulated cluster, and print the latency
//! story — unoptimized vs adaptive buffer sizing vs + dynamic chaining.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use nephele::config::EngineConfig;
use nephele::experiments::video_scenarios::{run_video_scenario, Scenario};
use nephele::pipeline::video::VideoSpec;

fn main() -> anyhow::Result<()> {
    let spec = VideoSpec::small();
    println!(
        "video job: {} task types x m={} on {} workers, {} streams at {} fps",
        6, spec.parallelism, spec.workers, spec.streams, spec.fps
    );
    println!("constraint: {} ms over every (e1,D,e2,M,e3,O,e4,E,e5) sequence\n", spec.constraint_ms);

    let mut rows = Vec::new();
    for scenario in [
        Scenario::Unoptimized,
        Scenario::AdaptiveBuffers,
        Scenario::BuffersAndChaining,
    ] {
        // The chaining scenario uses the constraint scaled to our
        // substrate's buffers-only plateau (see EXPERIMENTS.md §Fig.9).
        let mut spec = spec;
        if scenario == Scenario::BuffersAndChaining {
            spec.constraint_ms = 107;
        }
        let r = run_video_scenario(scenario, spec, EngineConfig::default(), 600, 60, false)?;
        println!("== {} ==", r.scenario.title());
        print!("{}", r.final_breakdown.render());
        println!();
        rows.push((r.scenario.title(), r.converged_total_ms(), r.chains_established));
    }

    println!("summary:");
    for (title, total, chains) in &rows {
        println!("  {title:<64} {total:>9.1} ms   chains={chains}");
    }
    let factor = rows[0].1 / rows[2].1;
    println!(
        "\nimprovement factor (unoptimized -> fully optimized): {factor:.1}x (paper: >=13x)"
    );
    Ok(())
}
