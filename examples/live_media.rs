//! End-to-end LIVE driver: the citizen-journalism pipeline with REAL
//! compute on the request path — every frame group runs through the
//! AOT-compiled XLA stages (JAX/Pallas -> HLO text -> PJRT CPU), while
//! the real QoS manager watches the real measurements and applies both
//! countermeasures:
//!
//! * adaptive output buffer sizing shrinks the producer's batch buffer
//!   (initially 8 MB, i.e. dozens of frame groups per flush), and
//! * dynamic task chaining swaps the four per-stage executables for the
//!   fused `chained` artifact.
//!
//! Python never runs here: `make artifacts` must have produced
//! `artifacts/*.hlo.txt` beforehand.
//!
//! ```text
//! cargo run --release --example live_media
//! ```

use nephele::live::{run_live, LiveConfig};

fn main() -> anyhow::Result<()> {
    let cfg = LiveConfig::default();
    println!(
        "live media pipeline: {} frame groups at {} fps, 240x320 frames (merged 480x640)",
        cfg.frames, cfg.fps
    );
    println!(
        "initial output buffer {} KB, constraint {} ms, measurement interval {} ms\n",
        cfg.initial_buffer / 1024,
        cfg.constraint_ms,
        cfg.interval_ms
    );
    println!("running (real XLA compute on the PJRT CPU client)...\n");

    let report = run_live(&cfg)?;

    let p = |label: &str, s: &nephele::live::StageLatencies| {
        println!("{label} ({} frame groups):", s.frames);
        println!("  channel (buffer+transfer)   {:>9.2} ms", s.channel_ms);
        println!("  Decoder  (4x idct kernels)  {:>9.2} ms", s.decode_ms);
        println!("  Merger   (tile kernel)      {:>9.2} ms", s.merge_ms);
        println!("  Overlay  (blend kernel)     {:>9.2} ms", s.overlay_ms);
        println!("  Encoder  (dct kernel)       {:>9.2} ms", s.encode_ms);
        println!("  total                       {:>9.2} ms\n", s.total_ms);
    };
    p("before optimization", &report.before);
    p("after optimization", &report.after);
    println!(
        "buffer updates applied: {} (final size {} KB) | chained: {}",
        report.buffer_updates,
        report.final_buffer.div_ceil(1024),
        report.chained
    );
    println!(
        "end-to-end latency improvement: {:.1}x",
        report.improvement_factor
    );
    Ok(())
}
