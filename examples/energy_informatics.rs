//! Energy informatics (§1, second motivating scenario): a smart-meter
//! analytics pipeline where "the freshness of the data that is being
//! acted upon is of paramount importance".  4096 meters report every
//! 500 ms; the control path carries a 200 ms latency constraint.
//!
//! ```text
//! cargo run --release --example energy_informatics
//! ```

use nephele::config::EngineConfig;
use nephele::pipeline::meter::{smart_meter_job, MeterSpec};
use nephele::sim::cluster::SimCluster;
use nephele::sim::metrics::breakdown;
use nephele::util::time::Duration;

fn run(cfg: EngineConfig, label: &str) -> anyhow::Result<f64> {
    let (job, rg, constraints, specs, sources, seq) = smart_meter_job(MeterSpec::default())?;
    let mut cluster = SimCluster::new(job, rg, &constraints, specs, sources, cfg)?;
    cluster.run(Duration::from_secs(1500), None);
    let now = cluster.now();
    let b = breakdown(&mut cluster, &seq, now);
    println!("== {label} ==");
    print!("{}", b.render());
    println!(
        "ground-truth e2e mean: {} ms | buffer updates: {} | chains: {}\n",
        cluster.mean_e2e_ms().map_or("n/a".into(), |v| format!("{v:.1}")),
        cluster.stats.buffer_size_updates,
        cluster.stats.chains_established,
    );
    Ok(b.total_ms())
}

fn main() -> anyhow::Result<()> {
    let spec = MeterSpec::default();
    println!(
        "smart-meter job: {} meters, {} feeders, reporting every {}, constraint {} ms\n",
        spec.meters,
        spec.meters / spec.meters_per_feeder,
        spec.report_interval,
        spec.constraint_ms
    );
    let unopt = run(EngineConfig::default().unoptimized(), "without QoS optimization")?;
    let opt = run(EngineConfig::default().fully_optimized(), "with QoS optimization")?;
    println!("control-path latency: {unopt:.1} ms -> {opt:.1} ms ({:.1}x)", unopt / opt);
    Ok(())
}
